//! GPU cost model (dual NVIDIA RTX A5000, the paper's Table II column).
//!
//! GPU TFHE (Concrete-CUDA style) is throughput-oriented: PBS batches are
//! bandwidth-bound on BSK streaming, with a fixed per-launch overhead that
//! hurts serial (small-batch) workloads — which is why the paper's GPU
//! column sometimes loses to the CPU on shallow-parallel programs.

use crate::compiler::Compiled;

use super::cpu_model;

#[derive(Debug, Clone)]
pub struct GpuPlatform {
    pub name: &'static str,
    pub devices: usize,
    /// Per-device memory bandwidth, GB/s.
    pub bw_gbps: f64,
    /// Per-device effective rate on the f64 torus-FFT hot loop at full
    /// occupancy, GFLOP/s. Far below the A5000's FP32 peak: measured
    /// Concrete-CUDA PBS latencies (~5-6 ms at N=2048) put the effective
    /// rate at tens of GFLOP/s — calibrated against Table II.
    pub gflops: f64,
    /// Batch size per device below which SMs idle (occupancy knee).
    pub occupancy_knee: f64,
    /// Kernel-launch + host sync overhead per dependent PBS level.
    pub launch_overhead_s: f64,
    /// Device memory per GPU, GB (GPT-2 12-head OOMs at 24 GB each).
    pub mem_gb: f64,
}

pub const DUAL_A5000: GpuPlatform = GpuPlatform {
    name: "2x RTX A5000",
    devices: 2,
    bw_gbps: 768.0,
    gflops: 65.0,
    occupancy_knee: 16.0,
    launch_overhead_s: 450e-6,
    mem_gb: 24.0,
};

/// Program working-set estimate: keys + per-PBS accumulators without
/// ACC-dedup (the GPU library the paper used does not share accumulators),
/// double-buffered at runtime (input accumulator + rotated copy per PBS).
pub fn working_set_bytes(c: &Compiled) -> f64 {
    let p = &c.params;
    (p.bsk_bytes() + p.ksk_bytes()) as f64 + 2.0 * c.acc_dedup.bytes_before as f64
}

/// Does this program fit in device memory? (Table II: GPT-2 12-head OOM.)
pub fn fits(c: &Compiled, gpu: &GpuPlatform) -> bool {
    working_set_bytes(c) <= gpu.devices as f64 * gpu.mem_gb * 1e9
}

/// Wall-clock of a compiled program.
pub fn program_seconds(c: &Compiled, gpu: &GpuPlatform) -> f64 {
    let p = &c.params;
    let flops = cpu_model::pbs_flops(p);
    let bytes = cpu_model::pbs_bytes(p);
    let mut total = 0.0;
    for cts in cpu_model::level_widths(c) {
        let cts = cts.max(1) as f64;
        // Batch splits across devices; each device streams the BSK once
        // per batch and computes its ciphertexts. Small batches leave SMs
        // idle (occupancy knee) — this is why the GPU column of Table II
        // sometimes loses to the 48-core CPU on shallow-parallel programs.
        let per_dev = (cts / gpu.devices as f64).ceil();
        let occupancy = (per_dev / gpu.occupancy_knee).min(1.0);
        let compute = per_dev * flops / (gpu.gflops * 1e9 * occupancy);
        let mem = (bytes + per_dev * 2.0 * p.glwe_bytes() as f64) / (gpu.bw_gbps * 1e9);
        total += compute.max(mem) + gpu.launch_overhead_s;
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::cpu_model::{program_seconds as cpu_seconds, EPYC_7R13};
    use crate::compiler::compile;
    use crate::ir::builder::ProgramBuilder;
    use crate::params::GPT2;

    fn wide(n: usize) -> crate::ir::Program {
        let mut b = ProgramBuilder::new("w", 6);
        let xs = b.inputs(n);
        for x in xs {
            let y = b.lut_fn(x, |m| m);
            b.output(y);
        }
        b.finish()
    }

    fn chain(len: usize) -> crate::ir::Program {
        let mut b = ProgramBuilder::new("c", 6);
        let mut x = b.input();
        for _ in 0..len {
            x = b.lut_fn(x, |m| m);
        }
        b.output(x);
        b.finish()
    }

    #[test]
    fn gpu_wins_on_parallel_loses_on_serial() {
        // Table II pattern: GPU beats CPU on deep parallel workloads
        // (GPT-2, XGBoost) but can lose on shallow/serial ones (CNNs with
        // modest level parallelism per batch).
        let par = compile(&wide(2000), &GPT2, 48usize);
        let ser = compile(&chain(200), &GPT2, 48usize);
        let gpu_par = program_seconds(&par, &DUAL_A5000);
        let cpu_par = cpu_seconds(&par, &EPYC_7R13);
        assert!(gpu_par < cpu_par, "gpu {gpu_par} vs cpu {cpu_par}");
        let gpu_ser = program_seconds(&ser, &DUAL_A5000);
        let cpu_ser = cpu_seconds(&ser, &EPYC_7R13);
        // Serial: launch overhead + unused width make the GPU no better
        // than ~the CPU.
        assert!(gpu_ser > 0.5 * cpu_ser, "gpu {gpu_ser} vs cpu {cpu_ser}");
    }

    #[test]
    fn oom_detection_scales_with_acc_storage() {
        let small = compile(&wide(10), &GPT2, 48usize);
        assert!(fits(&small, &DUAL_A5000));
        // A program with ~200k distinct accumulators at N=32768 exceeds
        // 48 GB.
        let mut b = ProgramBuilder::new("huge", 6);
        let xs = b.inputs(1000);
        for (i, x) in xs.into_iter().enumerate() {
            let y = b.lut_fn(x, move |m| (m + i as u64) % 128);
            b.output(y);
        }
        let huge = compile(&b.finish(), &GPT2, 48usize);
        // 1000 distinct tables x 512 KB accumulators = 0.5 GB — still fits;
        // verify the arithmetic path rather than an absurd build time.
        assert!(working_set_bytes(&huge) > working_set_bytes(&small));
    }
}
