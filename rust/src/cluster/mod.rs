//! Sharded cluster serving — the scaling layer ABOVE one engine.
//!
//! The paper's serving story (Observation 7) harvests accelerator
//! parallelism by batching real queries into one worker pool; this module
//! is the next step the ROADMAP names: throughput beyond a single
//! accelerator comes from replicating the whole engine behind a router —
//! the organization MATCHA uses across TFHE clusters and HEAX across
//! replicated pipeline lanes.
//!
//! A [`Cluster`] owns N [`Coordinator`](crate::coordinator::Coordinator)
//! shards that all execute ONE shared
//! [`CompiledPlan`](crate::compiler::CompiledPlan) (compiled once, so
//! measured counters still cross-check `arch::sim` exactly — per shard and
//! in aggregate), each resolving session keys through its own shard-local
//! [`KeyStore`](crate::tenant::KeyStore). A [`Router`] places each
//! request by a pluggable [`PlacementPolicy`] (round-robin,
//! least-outstanding, or consistent-hash on the session id — the affinity
//! policy that keeps a tenant's key material warm on one shard); a
//! bounded shared admission queue turns overload into fast
//! [`ClusterError::ClusterFull`] errors instead of unbounded queueing;
//! [`Cluster::snapshot`] merges per-shard metrics (latency percentiles,
//! per-tenant request counts, key-cache counters) via
//! [`MetricsSnapshot::merge`](crate::coordinator::MetricsSnapshot::merge);
//! and [`Cluster::reshard`] changes the shard count live — draining
//! in-flight work, rebuilding the hash ring, and migrating the key-cache
//! entries whose ring ownership moved.
//!
//! The cluster is also the fault-tolerance layer: a supervisor thread
//! tracks per-shard health ([`HealthState`], from consecutive batch
//! failures and queue age), placement skips `Down` shards, failed
//! requests are retried on healthy shards within a bounded budget
//! ([`SupervisorOptions`]), and a shard that keeps failing is quarantined
//! and restarted over its existing key store. Growth past fixed per-shard
//! key material is a typed [`ReshardError`], not a panic.

pub mod router;
pub mod serve;

pub use router::{HealthState, PlacementPolicy, Router};
pub use serve::{
    Cluster, ClusterError, ClusterOptions, ClusterResponse, ReshardError, ReshardReport,
    StoreFactory, SupervisorOptions,
};
// Client-uploaded keys are rejected typed (never a panic) by
// `Cluster::register_session`; the error type lives with the stores.
pub use crate::tenant::RegisterError;
