//! Request placement across coordinator shards.
//!
//! Three policies, mirroring the trade-offs of replicated-engine FHE
//! serving (MATCHA's multi-cluster organization, HEAX's replicated
//! pipeline lanes):
//!
//! - **round-robin** — uniform spray, best for homogeneous traffic;
//! - **least-outstanding** — joins the shortest per-shard queue, best when
//!   request costs vary or shards are heterogeneous;
//! - **consistent-hash** on the client id — pins a client to one shard so
//!   per-client state (key caches, session accumulators) stays warm; the
//!   hash ring keeps most assignments stable when the shard count changes.
//!
//! The router also tracks per-shard **health** ([`HealthState`]), fed by
//! the cluster supervisor from two signals: consecutive batch failures
//! ([`Router::record_failure`]) and queue age ([`Router::set_stall`]).
//! Placement skips `Down` shards — each policy falls forward to its next
//! deterministic choice — and degrades gracefully to the original pick
//! when every shard is down (the submit then fails with a typed error
//! instead of misrouting silently).

use std::sync::atomic::{AtomicU32, AtomicU8, AtomicUsize, Ordering};

/// Supervisor's view of one shard. Order matters: `Down` is worse than
/// `Degraded` is worse than `Healthy`, and a shard's effective health is
/// the max of its failure-streak and stall signals.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum HealthState {
    /// Serving normally; placement considers it.
    Healthy,
    /// Recent failures or an aging queue; still placed (the shard is
    /// recovering), but one more strike downs it.
    Degraded,
    /// Quarantined: placement skips it until the supervisor restarts it
    /// and marks it healthy.
    Down,
}

impl HealthState {
    fn from_u8(v: u8) -> Self {
        match v {
            0 => Self::Healthy,
            1 => Self::Degraded,
            _ => Self::Down,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::Healthy => "healthy",
            Self::Degraded => "degraded",
            Self::Down => "down",
        }
    }
}

/// How the [`Router`](Router) picks a shard for a request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PlacementPolicy {
    /// Cycle through shards in submission order.
    RoundRobin,
    /// Send to the shard with the fewest outstanding requests.
    LeastOutstanding,
    /// Hash the client id onto a virtual-node ring (key affinity).
    ConsistentHash,
}

impl PlacementPolicy {
    /// Parse a CLI spelling (`round-robin` | `least-outstanding` |
    /// `consistent-hash`, with short aliases `rr` | `least` | `hash`),
    /// case-insensitively — `Round-Robin` in a config file must not
    /// silently fall back to a default.
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "round-robin" | "rr" => Some(Self::RoundRobin),
            "least-outstanding" | "least" => Some(Self::LeastOutstanding),
            "consistent-hash" | "hash" => Some(Self::ConsistentHash),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::RoundRobin => "round-robin",
            Self::LeastOutstanding => "least-outstanding",
            Self::ConsistentHash => "consistent-hash",
        }
    }
}

/// FNV-1a 64-bit — deterministic across runs (unlike `DefaultHasher`), so
/// client -> shard pinning survives restarts and is testable.
fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Murmur3 finalizer on top of FNV: raw FNV-1a over the mostly-zero
/// little-endian labels below disperses badly (measured: up to 88% of the
/// key space on one of 4 shards at high vnode counts); the avalanche
/// step restores an even split.
fn mix64(mut h: u64) -> u64 {
    h ^= h >> 33;
    h = h.wrapping_mul(0xff51_afd7_ed55_8ccd);
    h ^= h >> 33;
    h = h.wrapping_mul(0xc4ce_b9fe_1a85_ec53);
    h ^ (h >> 33)
}

/// Hash for ring points and client ids.
fn point(bytes: &[u8]) -> u64 {
    mix64(fnv1a(bytes))
}

/// Virtual nodes per shard on the consistent-hash ring. With the mixed
/// hash, 128 points/shard keeps every shard within ~20% of the ideal
/// share for 2-8 shards (simulated over 1000 uniform client ids).
const VNODES: usize = 128;

/// Stateless-per-request placement engine (the round-robin cursor is the
/// only internal state, and it is atomic so `&self` placement is safe
/// from any submitting thread).
#[derive(Debug)]
pub struct Router {
    policy: PlacementPolicy,
    shards: usize,
    rr_next: AtomicUsize,
    /// Sorted (point, shard) virtual nodes; empty unless consistent-hash.
    ring: Vec<(u64, usize)>,
    /// Consecutive batch-failure count per shard (reset by
    /// [`Self::mark_healthy`]).
    fail_streak: Vec<AtomicU32>,
    /// Queue-age signal per shard, encoded as [`HealthState`] in a `u8`
    /// (recomputed each supervisor tick, so it clears itself when the
    /// shard makes progress again).
    stall: Vec<AtomicU8>,
    /// Consecutive failures at which a shard goes `Down`.
    down_after: u32,
}

/// Consecutive failures before quarantine, absent an explicit setting.
pub(crate) const DEFAULT_DOWN_AFTER: u32 = 3;

impl Router {
    pub fn new(policy: PlacementPolicy, shards: usize) -> Self {
        Self::new_with_health(policy, shards, DEFAULT_DOWN_AFTER)
    }

    /// A router whose shards go `Down` after `down_after` consecutive
    /// recorded failures (`>= 1`).
    pub fn new_with_health(policy: PlacementPolicy, shards: usize, down_after: u32) -> Self {
        assert!(shards >= 1, "router needs at least one shard");
        assert!(down_after >= 1, "down_after 0 would quarantine healthy shards");
        let mut ring = Vec::new();
        if policy == PlacementPolicy::ConsistentHash {
            ring.reserve(shards * VNODES);
            for shard in 0..shards {
                for v in 0..VNODES {
                    let mut label = [0u8; 16];
                    label[..8].copy_from_slice(&(shard as u64).to_le_bytes());
                    label[8..].copy_from_slice(&(v as u64).to_le_bytes());
                    ring.push((point(&label), shard));
                }
            }
            ring.sort_unstable();
        }
        Self {
            policy,
            shards,
            rr_next: AtomicUsize::new(0),
            ring,
            fail_streak: (0..shards).map(|_| AtomicU32::new(0)).collect(),
            stall: (0..shards).map(|_| AtomicU8::new(0)).collect(),
            down_after,
        }
    }

    pub fn policy(&self) -> PlacementPolicy {
        self.policy
    }

    /// Record one batch failure on `shard`; returns its new effective
    /// health (consecutive-failure signal: 1 strike degrades,
    /// `down_after` strikes quarantine).
    pub fn record_failure(&self, shard: usize) -> HealthState {
        self.fail_streak[shard].fetch_add(1, Ordering::SeqCst);
        self.health(shard)
    }

    /// Clear `shard`'s failure streak and stall signal (after a restart,
    /// or on observed success).
    pub fn mark_healthy(&self, shard: usize) {
        self.fail_streak[shard].store(0, Ordering::SeqCst);
        self.stall[shard].store(0, Ordering::SeqCst);
    }

    /// Set `shard`'s queue-age signal (the supervisor recomputes this
    /// every tick from the shard's time-since-progress, so it is a level,
    /// not a latch).
    pub fn set_stall(&self, shard: usize, state: HealthState) {
        self.stall[shard].store(state as u8, Ordering::SeqCst);
    }

    /// Effective health: the worse of the failure-streak and queue-age
    /// signals.
    pub fn health(&self, shard: usize) -> HealthState {
        let streak = self.fail_streak[shard].load(Ordering::SeqCst);
        let from_streak = if streak >= self.down_after {
            HealthState::Down
        } else if streak >= 1 {
            HealthState::Degraded
        } else {
            HealthState::Healthy
        };
        let from_stall = HealthState::from_u8(self.stall[shard].load(Ordering::SeqCst));
        from_streak.max(from_stall)
    }

    /// Effective health of every shard, indexed by shard id.
    pub fn healths(&self) -> Vec<HealthState> {
        (0..self.shards).map(|s| self.health(s)).collect()
    }

    fn is_down(&self, shard: usize) -> bool {
        self.health(shard) == HealthState::Down
    }

    /// Pick the shard for one request, skipping `Down` shards. `outstanding`
    /// supplies the current per-shard inflight counts; it is a closure so
    /// the other policies don't pay for gathering counts they never read.
    /// With every shard healthy each policy picks exactly what it always
    /// did; with every shard down the original pick is returned and the
    /// submit fails downstream with a typed error.
    pub fn place(&self, client_id: u64, outstanding: impl FnOnce() -> Vec<usize>) -> usize {
        match self.policy {
            PlacementPolicy::RoundRobin => {
                let cursor = self.rr_next.fetch_add(1, Ordering::Relaxed) % self.shards;
                // Walk forward from the cursor to the first live shard;
                // offset 0 is the cursor itself, so the healthy path is
                // bit-identical to plain round-robin.
                (0..self.shards)
                    .map(|k| (cursor + k) % self.shards)
                    .find(|&s| !self.is_down(s))
                    .unwrap_or(cursor)
            }
            // Keyed (n, i) so ties deterministically break to the lowest
            // index (`min_by_key` alone keeps the *last* minimum).
            PlacementPolicy::LeastOutstanding => {
                let counts = outstanding();
                debug_assert_eq!(counts.len(), self.shards);
                let pick = |include_down: bool| {
                    counts
                        .iter()
                        .enumerate()
                        .filter(|&(i, _)| include_down || !self.is_down(i))
                        .min_by_key(|&(i, &n)| (n, i))
                        .map(|(i, _)| i)
                };
                pick(false).or_else(|| pick(true)).unwrap_or(0)
            }
            PlacementPolicy::ConsistentHash => {
                let h = point(&client_id.to_le_bytes());
                let i = self.ring.partition_point(|&(p, _)| p < h);
                // Walk the ring past down shards: the fallback owner is
                // the next live shard clockwise, the standard ring
                // fail-over (deterministic per client).
                (0..self.ring.len())
                    .map(|k| self.ring[(i + k) % self.ring.len()].1)
                    .find(|&s| !self.is_down(s))
                    .unwrap_or(self.ring[i % self.ring.len()].1)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn no_counts() -> Vec<usize> {
        panic!("this policy must not gather outstanding counts")
    }

    #[test]
    fn round_robin_cycles() {
        let r = Router::new(PlacementPolicy::RoundRobin, 3);
        let picks: Vec<usize> = (0..6).map(|_| r.place(0, no_counts)).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn least_outstanding_joins_shortest_queue() {
        let r = Router::new(PlacementPolicy::LeastOutstanding, 3);
        assert_eq!(r.place(0, || vec![4, 1, 2]), 1);
        assert_eq!(r.place(0, || vec![0, 0, 0]), 0, "ties break to the lowest index");
        assert_eq!(r.place(9, || vec![3, 3, 2]), 2);
    }

    #[test]
    fn consistent_hash_is_deterministic_per_client() {
        let r = Router::new(PlacementPolicy::ConsistentHash, 4);
        for client in 0..50u64 {
            let first = r.place(client, no_counts);
            for _ in 0..5 {
                assert_eq!(r.place(client, no_counts), first, "client {client} moved");
            }
        }
    }

    #[test]
    fn consistent_hash_spreads_clients_over_all_shards() {
        let shards = 4;
        let r = Router::new(PlacementPolicy::ConsistentHash, shards);
        let mut counts = vec![0usize; shards];
        for client in 0..1000u64 {
            counts[r.place(client, no_counts)] += 1;
        }
        for (s, &c) in counts.iter().enumerate() {
            // Ideal is 250; the mixed ring keeps every shard well within
            // 2x of it (measured [238, 232, 302, 228] at this seed-free
            // construction).
            assert!(c >= 125, "shard {s} badly underloaded: {counts:?}");
            assert!(c <= 500, "shard {s} badly overloaded: {counts:?}");
        }
    }

    #[test]
    fn consistent_hash_is_mostly_stable_under_resharding() {
        // Growing 3 -> 4 shards should move well under half the clients
        // (the whole point of the ring vs `hash % shards`).
        let r3 = Router::new(PlacementPolicy::ConsistentHash, 3);
        let r4 = Router::new(PlacementPolicy::ConsistentHash, 4);
        let moved = (0..1000u64)
            .filter(|&c| r3.place(c, no_counts) != r4.place(c, no_counts))
            .count();
        assert!(moved < 500, "{moved}/1000 clients moved on reshard");
    }

    #[test]
    fn policy_parse_roundtrip() {
        for p in [
            PlacementPolicy::RoundRobin,
            PlacementPolicy::LeastOutstanding,
            PlacementPolicy::ConsistentHash,
        ] {
            assert_eq!(PlacementPolicy::parse(p.name()), Some(p));
        }
        assert_eq!(PlacementPolicy::parse("rr"), Some(PlacementPolicy::RoundRobin));
        assert_eq!(PlacementPolicy::parse("nope"), None);
    }

    #[test]
    fn policy_parse_is_case_insensitive() {
        assert_eq!(PlacementPolicy::parse("Round-Robin"), Some(PlacementPolicy::RoundRobin));
        assert_eq!(PlacementPolicy::parse("RR"), Some(PlacementPolicy::RoundRobin));
        assert_eq!(
            PlacementPolicy::parse("LEAST-OUTSTANDING"),
            Some(PlacementPolicy::LeastOutstanding)
        );
        assert_eq!(PlacementPolicy::parse("Hash"), Some(PlacementPolicy::ConsistentHash));
        assert_eq!(
            PlacementPolicy::parse("Consistent-Hash"),
            Some(PlacementPolicy::ConsistentHash)
        );
    }

    #[test]
    fn failure_streak_degrades_then_downs_and_mark_healthy_resets() {
        let r = Router::new_with_health(PlacementPolicy::RoundRobin, 2, 3);
        assert_eq!(r.health(0), HealthState::Healthy);
        assert_eq!(r.record_failure(0), HealthState::Degraded);
        assert_eq!(r.record_failure(0), HealthState::Degraded);
        assert_eq!(r.record_failure(0), HealthState::Down);
        assert_eq!(r.healths(), vec![HealthState::Down, HealthState::Healthy]);
        r.mark_healthy(0);
        assert_eq!(r.health(0), HealthState::Healthy);
        // Stall is a level combined by max: a degraded stall on a shard
        // with failures keeps the worse state.
        r.set_stall(1, HealthState::Down);
        assert_eq!(r.health(1), HealthState::Down);
        r.set_stall(1, HealthState::Healthy);
        assert_eq!(r.health(1), HealthState::Healthy);
    }

    #[test]
    fn round_robin_skips_down_shards_and_recovers() {
        let r = Router::new_with_health(PlacementPolicy::RoundRobin, 3, 1);
        assert_eq!(r.record_failure(1), HealthState::Down);
        let picks: Vec<usize> = (0..6).map(|_| r.place(0, no_counts)).collect();
        assert_eq!(picks, vec![0, 2, 2, 0, 2, 2], "cursor 1 falls forward to shard 2");
        r.mark_healthy(1);
        let picks: Vec<usize> = (0..3).map(|_| r.place(0, no_counts)).collect();
        assert_eq!(picks, vec![0, 1, 2], "restored shard rejoins the cycle");
    }

    #[test]
    fn least_outstanding_ignores_down_shards_unless_all_down() {
        let r = Router::new_with_health(PlacementPolicy::LeastOutstanding, 3, 1);
        r.record_failure(0);
        assert_eq!(r.place(0, || vec![0, 4, 2]), 2, "shortest live queue, not the down shard");
        r.record_failure(1);
        r.record_failure(2);
        assert_eq!(r.place(0, || vec![0, 4, 2]), 0, "all down: degrade to the plain pick");
    }

    #[test]
    fn consistent_hash_fails_over_deterministically_and_returns_home() {
        let r = Router::new_with_health(PlacementPolicy::ConsistentHash, 4, 1);
        let homes: Vec<usize> = (0..50u64).map(|c| r.place(c, no_counts)).collect();
        let down = homes[0];
        r.record_failure(down);
        for (c, &home) in homes.iter().enumerate() {
            let moved = r.place(c as u64, no_counts);
            assert_ne!(moved, down, "client {c} placed on a down shard");
            if home != down {
                assert_eq!(moved, home, "client {c} moved although its home shard is live");
            } else {
                // Fail-over target is stable per client.
                assert_eq!(r.place(c as u64, no_counts), moved);
            }
        }
        r.mark_healthy(down);
        for (c, &home) in homes.iter().enumerate() {
            assert_eq!(r.place(c as u64, no_counts), home, "client {c} must return home");
        }
    }

    #[test]
    fn place_never_gathers_counts_for_policies_that_ignore_them() {
        // Gathering outstanding counts walks every shard's atomic; only
        // least-outstanding may pay that. The closure panics, so any
        // spurious invocation fails loudly across many placements.
        for policy in [PlacementPolicy::RoundRobin, PlacementPolicy::ConsistentHash] {
            let r = Router::new(policy, 4);
            for client in 0..64u64 {
                r.place(client, || -> Vec<usize> {
                    panic!("{} must not gather outstanding counts", policy.name())
                });
            }
        }
    }
}
