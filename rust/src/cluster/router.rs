//! Request placement across coordinator shards.
//!
//! Three policies, mirroring the trade-offs of replicated-engine FHE
//! serving (MATCHA's multi-cluster organization, HEAX's replicated
//! pipeline lanes):
//!
//! - **round-robin** — uniform spray, best for homogeneous traffic;
//! - **least-outstanding** — joins the shortest per-shard queue, best when
//!   request costs vary or shards are heterogeneous;
//! - **consistent-hash** on the client id — pins a client to one shard so
//!   per-client state (key caches, session accumulators) stays warm; the
//!   hash ring keeps most assignments stable when the shard count changes.

use std::sync::atomic::{AtomicUsize, Ordering};

/// How the [`Router`](Router) picks a shard for a request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PlacementPolicy {
    /// Cycle through shards in submission order.
    RoundRobin,
    /// Send to the shard with the fewest outstanding requests.
    LeastOutstanding,
    /// Hash the client id onto a virtual-node ring (key affinity).
    ConsistentHash,
}

impl PlacementPolicy {
    /// Parse a CLI spelling (`round-robin` | `least-outstanding` |
    /// `consistent-hash`, with short aliases `rr` | `least` | `hash`),
    /// case-insensitively — `Round-Robin` in a config file must not
    /// silently fall back to a default.
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "round-robin" | "rr" => Some(Self::RoundRobin),
            "least-outstanding" | "least" => Some(Self::LeastOutstanding),
            "consistent-hash" | "hash" => Some(Self::ConsistentHash),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::RoundRobin => "round-robin",
            Self::LeastOutstanding => "least-outstanding",
            Self::ConsistentHash => "consistent-hash",
        }
    }
}

/// FNV-1a 64-bit — deterministic across runs (unlike `DefaultHasher`), so
/// client -> shard pinning survives restarts and is testable.
fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Murmur3 finalizer on top of FNV: raw FNV-1a over the mostly-zero
/// little-endian labels below disperses badly (measured: up to 88% of the
/// key space on one of 4 shards at high vnode counts); the avalanche
/// step restores an even split.
fn mix64(mut h: u64) -> u64 {
    h ^= h >> 33;
    h = h.wrapping_mul(0xff51_afd7_ed55_8ccd);
    h ^= h >> 33;
    h = h.wrapping_mul(0xc4ce_b9fe_1a85_ec53);
    h ^ (h >> 33)
}

/// Hash for ring points and client ids.
fn point(bytes: &[u8]) -> u64 {
    mix64(fnv1a(bytes))
}

/// Virtual nodes per shard on the consistent-hash ring. With the mixed
/// hash, 128 points/shard keeps every shard within ~20% of the ideal
/// share for 2-8 shards (simulated over 1000 uniform client ids).
const VNODES: usize = 128;

/// Stateless-per-request placement engine (the round-robin cursor is the
/// only internal state, and it is atomic so `&self` placement is safe
/// from any submitting thread).
#[derive(Debug)]
pub struct Router {
    policy: PlacementPolicy,
    shards: usize,
    rr_next: AtomicUsize,
    /// Sorted (point, shard) virtual nodes; empty unless consistent-hash.
    ring: Vec<(u64, usize)>,
}

impl Router {
    pub fn new(policy: PlacementPolicy, shards: usize) -> Self {
        assert!(shards >= 1, "router needs at least one shard");
        let mut ring = Vec::new();
        if policy == PlacementPolicy::ConsistentHash {
            ring.reserve(shards * VNODES);
            for shard in 0..shards {
                for v in 0..VNODES {
                    let mut label = [0u8; 16];
                    label[..8].copy_from_slice(&(shard as u64).to_le_bytes());
                    label[8..].copy_from_slice(&(v as u64).to_le_bytes());
                    ring.push((point(&label), shard));
                }
            }
            ring.sort_unstable();
        }
        Self { policy, shards, rr_next: AtomicUsize::new(0), ring }
    }

    pub fn policy(&self) -> PlacementPolicy {
        self.policy
    }

    /// Pick the shard for one request. `outstanding` supplies the current
    /// per-shard inflight counts; it is a closure so the other policies
    /// don't pay for gathering counts they never read.
    pub fn place(&self, client_id: u64, outstanding: impl FnOnce() -> Vec<usize>) -> usize {
        match self.policy {
            PlacementPolicy::RoundRobin => {
                self.rr_next.fetch_add(1, Ordering::Relaxed) % self.shards
            }
            // Keyed (n, i) so ties deterministically break to the lowest
            // index (`min_by_key` alone keeps the *last* minimum).
            PlacementPolicy::LeastOutstanding => {
                let counts = outstanding();
                debug_assert_eq!(counts.len(), self.shards);
                counts
                    .iter()
                    .enumerate()
                    .min_by_key(|&(i, &n)| (n, i))
                    .map(|(i, _)| i)
                    .unwrap_or(0)
            }
            PlacementPolicy::ConsistentHash => {
                let h = point(&client_id.to_le_bytes());
                let i = self.ring.partition_point(|&(p, _)| p < h);
                self.ring[i % self.ring.len()].1
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn no_counts() -> Vec<usize> {
        panic!("this policy must not gather outstanding counts")
    }

    #[test]
    fn round_robin_cycles() {
        let r = Router::new(PlacementPolicy::RoundRobin, 3);
        let picks: Vec<usize> = (0..6).map(|_| r.place(0, no_counts)).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn least_outstanding_joins_shortest_queue() {
        let r = Router::new(PlacementPolicy::LeastOutstanding, 3);
        assert_eq!(r.place(0, || vec![4, 1, 2]), 1);
        assert_eq!(r.place(0, || vec![0, 0, 0]), 0, "ties break to the lowest index");
        assert_eq!(r.place(9, || vec![3, 3, 2]), 2);
    }

    #[test]
    fn consistent_hash_is_deterministic_per_client() {
        let r = Router::new(PlacementPolicy::ConsistentHash, 4);
        for client in 0..50u64 {
            let first = r.place(client, no_counts);
            for _ in 0..5 {
                assert_eq!(r.place(client, no_counts), first, "client {client} moved");
            }
        }
    }

    #[test]
    fn consistent_hash_spreads_clients_over_all_shards() {
        let shards = 4;
        let r = Router::new(PlacementPolicy::ConsistentHash, shards);
        let mut counts = vec![0usize; shards];
        for client in 0..1000u64 {
            counts[r.place(client, no_counts)] += 1;
        }
        for (s, &c) in counts.iter().enumerate() {
            // Ideal is 250; the mixed ring keeps every shard well within
            // 2x of it (measured [238, 232, 302, 228] at this seed-free
            // construction).
            assert!(c >= 125, "shard {s} badly underloaded: {counts:?}");
            assert!(c <= 500, "shard {s} badly overloaded: {counts:?}");
        }
    }

    #[test]
    fn consistent_hash_is_mostly_stable_under_resharding() {
        // Growing 3 -> 4 shards should move well under half the clients
        // (the whole point of the ring vs `hash % shards`).
        let r3 = Router::new(PlacementPolicy::ConsistentHash, 3);
        let r4 = Router::new(PlacementPolicy::ConsistentHash, 4);
        let moved = (0..1000u64)
            .filter(|&c| r3.place(c, no_counts) != r4.place(c, no_counts))
            .count();
        assert!(moved < 500, "{moved}/1000 clients moved on reshard");
    }

    #[test]
    fn policy_parse_roundtrip() {
        for p in [
            PlacementPolicy::RoundRobin,
            PlacementPolicy::LeastOutstanding,
            PlacementPolicy::ConsistentHash,
        ] {
            assert_eq!(PlacementPolicy::parse(p.name()), Some(p));
        }
        assert_eq!(PlacementPolicy::parse("rr"), Some(PlacementPolicy::RoundRobin));
        assert_eq!(PlacementPolicy::parse("nope"), None);
    }

    #[test]
    fn policy_parse_is_case_insensitive() {
        assert_eq!(PlacementPolicy::parse("Round-Robin"), Some(PlacementPolicy::RoundRobin));
        assert_eq!(PlacementPolicy::parse("RR"), Some(PlacementPolicy::RoundRobin));
        assert_eq!(
            PlacementPolicy::parse("LEAST-OUTSTANDING"),
            Some(PlacementPolicy::LeastOutstanding)
        );
        assert_eq!(PlacementPolicy::parse("Hash"), Some(PlacementPolicy::ConsistentHash));
        assert_eq!(
            PlacementPolicy::parse("Consistent-Hash"),
            Some(PlacementPolicy::ConsistentHash)
        );
    }

    #[test]
    fn place_never_gathers_counts_for_policies_that_ignore_them() {
        // Gathering outstanding counts walks every shard's atomic; only
        // least-outstanding may pay that. The closure panics, so any
        // spurious invocation fails loudly across many placements.
        for policy in [PlacementPolicy::RoundRobin, PlacementPolicy::ConsistentHash] {
            let r = Router::new(policy, 4);
            for client in 0..64u64 {
                r.place(client, || -> Vec<usize> {
                    panic!("{} must not gather outstanding counts", policy.name())
                });
            }
        }
    }
}
