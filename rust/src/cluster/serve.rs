//! The cluster proper: N coordinator shards behind one router, one shared
//! bounded admission queue, shard-local key stores, and merged
//! observability.
//!
//! The program is compiled ONCE ([`compiler::compile`]) and the resulting
//! [`CompiledPlan`] is shared by every shard's workers
//! ([`Coordinator::start_with_plan_store`]), so all shards execute — and
//! `arch::sim` costs — the identical artifact. Keys are resolved per
//! *session* through one [`KeyStore`] per shard: the compat constructors
//! wrap a single `Arc<ServerKeys>` in [`StaticKeys`] (replicated or
//! per-shard), while [`Cluster::start_with_store_factory`] installs
//! multi-tenant stores (e.g. `tenant::SeededTenantStore`) whose cached
//! key material lives shard-locally — which is exactly why consistent-hash
//! placement pins a session to one shard: its keys stay warm there.
//!
//! Admission is permit-based: [`Cluster::submit`] atomically claims one of
//! `queue_depth` slots and hands the permit to the returned
//! [`ClusterResponse`]; the slot is released when the client drops the
//! handle (normally right after `recv`). At depth, `submit` fails fast
//! with [`ClusterError::ClusterFull`] instead of queueing unboundedly —
//! callers shed load or retry after draining, exactly the backpressure a
//! front door needs at millions-of-users scale.
//!
//! [`Cluster::reshard`] changes the shard count live: admissions pause
//! (the call holds `&mut self`), every in-flight request drains through
//! its original shard, the consistent-hash ring is rebuilt, and
//! shard-local key-cache entries whose ring ownership moved are migrated
//! — evicted from the old owner's store and registered (same `Arc`, no
//! regeneration) into the new owner's.

use std::fmt;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{Receiver, RecvError};
use std::sync::Arc;

use super::router::{PlacementPolicy, Router};
use crate::compiler::{self, CompiledPlan};
use crate::coordinator::{Coordinator, CoordinatorOptions, MetricsSnapshot, SubmitError};
use crate::ir::Program;
use crate::tenant::{KeyStore, KeyStoreStats, SessionId, StaticKeys};
use crate::tfhe::{LweCiphertext, ServerKeys};

/// Builds the shard-local [`KeyStore`] for a shard index — how the
/// cluster creates stores at startup and for shards added by
/// [`Cluster::reshard`]. Factories for seeded tenant stores typically
/// ignore the index (every shard derives the same per-session bits from
/// the master seed); factories over fixed per-shard key vectors panic
/// past their length.
pub type StoreFactory = Arc<dyn Fn(usize) -> Arc<dyn KeyStore> + Send + Sync>;

#[derive(Debug, Clone)]
pub struct ClusterOptions {
    /// Number of coordinator shards (each with its own worker pool).
    pub shards: usize,
    /// How the router places requests onto shards.
    pub policy: PlacementPolicy,
    /// Cluster-wide admission bound: maximum outstanding responses before
    /// [`Cluster::submit`] returns [`ClusterError::ClusterFull`]. `None`
    /// admits without limit.
    pub queue_depth: Option<usize>,
    /// Per-shard coordinator configuration (workers, batcher, backend,
    /// optional per-shard `max_queue_depth`).
    pub coordinator: CoordinatorOptions,
}

impl Default for ClusterOptions {
    fn default() -> Self {
        Self {
            shards: 2,
            policy: PlacementPolicy::RoundRobin,
            queue_depth: None,
            coordinator: CoordinatorOptions::default(),
        }
    }
}

/// Error returned by [`Cluster::submit`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ClusterError {
    /// The shared admission queue is at `queue_depth` — shed load.
    ClusterFull,
    /// The routed shard's own `max_queue_depth` bound fired.
    ShardFull,
    /// The cluster (or the routed shard) has shut down.
    Stopped,
}

impl fmt::Display for ClusterError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ClusterError::ClusterFull => f.write_str("cluster admission queue full"),
            ClusterError::ShardFull => f.write_str("routed shard queue full"),
            ClusterError::Stopped => f.write_str("cluster stopped"),
        }
    }
}

impl std::error::Error for ClusterError {}

/// One slot in the shared admission queue; releases on drop.
#[derive(Debug)]
struct AdmissionPermit {
    admitted: Arc<AtomicUsize>,
}

impl AdmissionPermit {
    fn acquire(
        admitted: &Arc<AtomicUsize>,
        depth: Option<usize>,
    ) -> Result<Self, ClusterError> {
        if !crate::coordinator::server::try_claim_slot(admitted, depth) {
            return Err(ClusterError::ClusterFull);
        }
        Ok(Self { admitted: admitted.clone() })
    }
}

impl Drop for AdmissionPermit {
    fn drop(&mut self) {
        self.admitted.fetch_sub(1, Ordering::SeqCst);
    }
}

/// A pending response plus its admission slot. The slot frees when this
/// handle is dropped, so a client that holds N handles occupies N of the
/// cluster's `queue_depth` — backpressure is deterministic, independent of
/// worker timing.
#[derive(Debug)]
pub struct ClusterResponse {
    rx: Receiver<Vec<LweCiphertext>>,
    /// Which shard served this request (useful for affinity checks).
    pub shard: usize,
    _permit: AdmissionPermit,
}

impl ClusterResponse {
    /// Wait for the decryptable output ciphertexts.
    pub fn recv(&self) -> Result<Vec<LweCiphertext>, RecvError> {
        self.rx.recv()
    }
}

/// What one [`Cluster::reshard`] call did.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ReshardReport {
    pub old_shards: usize,
    pub new_shards: usize,
    /// Key-cache entries resident across all shard stores before the
    /// reshard.
    pub resident_before: usize,
    /// Entries whose ring ownership moved and that were re-registered
    /// into their new owner's store (consistent-hash policy; other
    /// policies migrate only entries orphaned by removed shards).
    pub migrated: usize,
    /// Entries resident across all shard stores after migration. Can be
    /// below `resident_before` on a shrink: target stores' capacity
    /// bounds bind during migration too, so a full target LRU-displaces
    /// (counted in its eviction stats) and the displaced tenants
    /// regenerate on next touch — *cache* residency never exceeds
    /// `capacity x shards` no matter how the topology moves. (Evicted
    /// material is freed once its last handle drops: each worker pins
    /// the key set it last executed and in-flight requests pin theirs,
    /// so peak key memory is `capacity x shards` plus up to one
    /// transient set per worker/in-flight handle.)
    pub resident_after: usize,
}

/// N replicated serving engines behind one admission-controlled router,
/// each shard resolving session keys through its own shard-local store.
pub struct Cluster {
    shards: Vec<Coordinator>,
    stores: Vec<Arc<dyn KeyStore>>,
    factory: StoreFactory,
    router: Router,
    coordinator_opts: CoordinatorOptions,
    admitted: Arc<AtomicUsize>,
    queue_depth: Option<usize>,
    plan: Arc<CompiledPlan>,
    accepting: bool,
    /// Metrics of shards drained by past reshards (request-path counters
    /// only — surviving stores keep reporting their own cumulative
    /// counters through the live shards).
    retired: Vec<MetricsSnapshot>,
    /// Final counters of stores dropped by past shrinks.
    retired_key_stats: KeyStoreStats,
}

impl Cluster {
    /// Start with replicated keys: every shard serves under the same
    /// `ServerKeys` (one [`StaticKeys`] wrapper per shard — no key
    /// material is copied, and per-shard store counters stay disjoint).
    pub fn start(program: Program, keys: Arc<ServerKeys>, opts: ClusterOptions) -> Self {
        let factory: StoreFactory =
            Arc::new(move |_shard| Arc::new(StaticKeys::new(keys.clone())) as Arc<dyn KeyStore>);
        Self::start_with_store_factory(program, factory, opts)
    }

    /// Start with per-shard keys (all generated for the same parameter
    /// set); `shard_keys.len()` overrides `opts.shards`. Growing past the
    /// provided keys via [`Self::reshard`] panics — fixed per-shard key
    /// vectors cannot invent material for new shards.
    pub fn start_with_shard_keys(
        program: Program,
        shard_keys: Vec<Arc<ServerKeys>>,
        opts: ClusterOptions,
    ) -> Self {
        assert!(!shard_keys.is_empty(), "cluster needs at least one shard");
        let mut opts = opts;
        opts.shards = shard_keys.len();
        let factory: StoreFactory = Arc::new(move |shard| {
            let keys = shard_keys
                .get(shard)
                .unwrap_or_else(|| {
                    panic!(
                        "no server keys for shard {shard}: start_with_shard_keys provided \
                         {} fixed key sets; growing needs start_with_store_factory",
                        shard_keys.len()
                    )
                })
                .clone();
            Arc::new(StaticKeys::new(keys)) as Arc<dyn KeyStore>
        });
        Self::start_with_store_factory(program, factory, opts)
    }

    /// Start with explicit shard-local stores (`stores.len()` overrides
    /// `opts.shards`). Growing past the provided stores via
    /// [`Self::reshard`] panics; use [`Self::start_with_store_factory`]
    /// when the cluster must be able to mint stores for new shards.
    pub fn start_with_stores(
        program: Program,
        stores: Vec<Arc<dyn KeyStore>>,
        opts: ClusterOptions,
    ) -> Self {
        assert!(!stores.is_empty(), "cluster needs at least one shard");
        let mut opts = opts;
        opts.shards = stores.len();
        let factory: StoreFactory = Arc::new(move |shard| {
            stores
                .get(shard)
                .unwrap_or_else(|| {
                    panic!(
                        "no key store for shard {shard}: start_with_stores provided {}; \
                         growing needs start_with_store_factory",
                        stores.len()
                    )
                })
                .clone()
        });
        Self::start_with_store_factory(program, factory, opts)
    }

    /// The primary session-keyed constructor: `factory(i)` builds shard
    /// `i`'s local [`KeyStore`] — at startup for `0..opts.shards` and
    /// again for any shard [`Self::reshard`] adds later.
    pub fn start_with_store_factory(
        program: Program,
        factory: StoreFactory,
        opts: ClusterOptions,
    ) -> Self {
        let shards = opts.shards;
        assert!(shards >= 1, "cluster needs at least one shard");
        assert_ne!(
            opts.queue_depth,
            Some(0),
            "queue_depth 0 would reject every request; use None for unbounded"
        );
        let mut stores: Vec<Arc<dyn KeyStore>> = Vec::with_capacity(shards);
        for i in 0..shards {
            stores.push(factory(i));
        }
        let params = stores[0].params().clone();
        assert!(
            stores.iter().all(|s| s.params().name == params.name),
            "all shards must use one parameter set"
        );
        // Compile once; every shard executes (and `arch::sim` costs) the
        // same artifact.
        let plan = Arc::new(compiler::compile(&program, &params, opts.coordinator.plan_capacity));
        let shard_coords: Vec<Coordinator> = stores
            .iter()
            .map(|store| {
                Coordinator::start_with_plan_store(
                    plan.clone(),
                    store.clone(),
                    opts.coordinator.clone(),
                )
            })
            .collect();
        let router = Router::new(opts.policy, shards);
        Self {
            shards: shard_coords,
            stores,
            factory,
            router,
            coordinator_opts: opts.coordinator,
            admitted: Arc::new(AtomicUsize::new(0)),
            queue_depth: opts.queue_depth,
            plan,
            accepting: true,
            retired: Vec::new(),
            retired_key_stats: KeyStoreStats::default(),
        }
    }

    /// The compiled plan every shard executes.
    pub fn plan(&self) -> &CompiledPlan {
        &self.plan
    }

    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    pub fn policy(&self) -> PlacementPolicy {
        self.router.policy()
    }

    /// The shard-local key stores, indexed by shard id.
    pub fn stores(&self) -> &[Arc<dyn KeyStore>] {
        &self.stores
    }

    /// Currently admitted (undropped) responses across the cluster.
    pub fn outstanding(&self) -> usize {
        self.admitted.load(Ordering::SeqCst)
    }

    /// Admit, route, and submit one encrypted query for `session` (plain
    /// `u64` client ids convert). The inputs are consumed either way; a
    /// single-submitter client that wants lossless backpressure should
    /// drain a pending response while [`Self::outstanding`] sits at the
    /// queue depth (as the drivers do) rather than bounce off
    /// [`ClusterError::ClusterFull`].
    pub fn submit(
        &self,
        session: impl Into<SessionId>,
        inputs: Vec<LweCiphertext>,
    ) -> Result<ClusterResponse, ClusterError> {
        if !self.accepting {
            return Err(ClusterError::Stopped);
        }
        let session = session.into();
        // The permit is dropped (slot released) on any error path below.
        let permit = AdmissionPermit::acquire(&self.admitted, self.queue_depth)?;
        // Outstanding counts are gathered lazily — only the
        // least-outstanding policy reads them.
        let shard = self.router.place(session.0, || {
            self.shards.iter().map(|c| c.inflight.load(Ordering::SeqCst)).collect()
        });
        let rx = self.shards[shard].submit_for(session, inputs).map_err(|e| match e {
            SubmitError::Stopped => ClusterError::Stopped,
            SubmitError::QueueFull => ClusterError::ShardFull,
        })?;
        Ok(ClusterResponse { rx, shard, _permit: permit })
    }

    /// Per-shard metrics (request-path counters + the shard store's key
    /// counters), indexed by shard id.
    pub fn shard_snapshots(&self) -> Vec<MetricsSnapshot> {
        self.shards.iter().map(|c| c.snapshot()).collect()
    }

    /// Aggregate cluster metrics: counters summed (including per-tenant
    /// request counts and key-cache counters), percentiles recomputed
    /// over the concatenated samples ([`MetricsSnapshot::merge`]).
    /// Includes shards drained by past [`Self::reshard`] calls, so totals
    /// are lifetime totals: every admitted request appears exactly once.
    pub fn snapshot(&self) -> MetricsSnapshot {
        let mut all = self.retired.clone();
        all.extend(self.shard_snapshots());
        let mut merged = MetricsSnapshot::merge(&all);
        merged.key_hits += self.retired_key_stats.hits;
        merged.key_misses += self.retired_key_stats.misses;
        merged.key_evictions += self.retired_key_stats.evictions;
        merged.key_regenerations += self.retired_key_stats.regenerations;
        merged
    }

    /// Live reshard to `new_shards` coordinator shards.
    ///
    /// Holding `&mut self` guarantees no concurrent [`Self::submit`]:
    /// admissions are paused for the duration. Every already-admitted
    /// request drains through its original shard (the per-shard shutdown
    /// flushes batchers and joins workers), so nothing is lost and
    /// nothing re-executes; undropped [`ClusterResponse`] handles keep
    /// their admission slots and deliver normally.
    ///
    /// Shard-local stores survive: shard `i < min(old, new)` keeps its
    /// store, new shards get `factory(i)` stores, and removed shards'
    /// stores are dropped after migration. Under the consistent-hash
    /// policy, every resident cache entry whose ring ownership changed is
    /// migrated (evict + register, preserving the `Arc` — no
    /// regeneration); the ring keeps most assignments stable, so only the
    /// ring-predicted fraction moves. Under other policies sessions have
    /// no shard affinity, so only entries orphaned by removed shards are
    /// rehomed (`session % new_shards`). Target capacity still binds: a
    /// shrink that funnels more entries into a store than it can hold
    /// LRU-displaces the excess (see [`ReshardReport::resident_after`]) —
    /// the displaced tenants regenerate on next touch rather than the
    /// cluster exceeding its residency bound.
    pub fn reshard(&mut self, new_shards: usize) -> ReshardReport {
        assert!(new_shards >= 1, "cluster needs at least one shard");
        let old_shards = self.shards.len();
        self.accepting = false;

        // Drain: every admitted request is answered by its original
        // shard before any topology change.
        for shard in &mut self.shards {
            shard.shutdown();
        }
        self.retired.extend(self.shards.iter().map(|c| c.metrics.snapshot()));
        self.shards.clear();

        // New ring first — migration targets are its ownership.
        let router = Router::new(self.router.policy(), new_shards);

        // Stores: survivors keep their index, new shards mint via the
        // factory.
        let mut stores: Vec<Arc<dyn KeyStore>> = Vec::with_capacity(new_shards);
        for i in 0..new_shards {
            match self.stores.get(i) {
                Some(s) => stores.push(s.clone()),
                None => stores.push((self.factory)(i)),
            }
        }

        // Migrate cache entries whose ownership moved. Residency is
        // snapshotted per store BEFORE any movement, so an entry migrated
        // into a store processed later is never re-considered (or
        // double-counted).
        let hash_affinity = self.router.policy() == PlacementPolicy::ConsistentHash;
        let resident: Vec<Vec<SessionId>> =
            self.stores.iter().map(|s| s.resident()).collect();
        let resident_before: usize = resident.iter().map(Vec::len).sum();
        let mut migrated = 0usize;
        for (i, (store, sessions)) in self.stores.iter().zip(resident).enumerate() {
            for session in sessions {
                let target = if hash_affinity {
                    router.place(session.0, || {
                        unreachable!("consistent hash never gathers outstanding counts")
                    })
                } else if i >= new_shards {
                    (session.0 % new_shards as u64) as usize
                } else {
                    i // no affinity, shard survives: leave the entry alone
                };
                if target == i {
                    continue;
                }
                let Some(keys) = store.evict(session) else {
                    continue; // raced out from under us; nothing to move
                };
                stores[target].register(session, keys);
                migrated += 1;
            }
        }
        // Account stats of stores that are going away (shrink).
        for dropped in self.stores.iter().skip(new_shards) {
            let st = dropped.stats();
            self.retired_key_stats.hits += st.hits;
            self.retired_key_stats.misses += st.misses;
            self.retired_key_stats.evictions += st.evictions;
            self.retired_key_stats.regenerations += st.regenerations;
        }

        let resident_after: usize = stores.iter().map(|s| s.resident().len()).sum();

        // Relaunch: same compiled plan, new shard set.
        self.shards = stores
            .iter()
            .map(|store| {
                Coordinator::start_with_plan_store(
                    self.plan.clone(),
                    store.clone(),
                    self.coordinator_opts.clone(),
                )
            })
            .collect();
        self.stores = stores;
        self.router = router;
        self.accepting = true;
        ReshardReport { old_shards, new_shards, resident_before, migrated, resident_after }
    }

    /// Graceful drain: stop admitting, flush every shard's batcher (all
    /// already-admitted requests are answered), and join dispatch + worker
    /// threads. Subsequent [`Self::submit`] calls return
    /// [`ClusterError::Stopped`].
    pub fn shutdown(&mut self) {
        self.accepting = false;
        for shard in &mut self.shards {
            shard.shutdown();
        }
    }
}
