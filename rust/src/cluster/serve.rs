//! The cluster proper: N coordinator shards behind one router, one shared
//! bounded admission queue, shard-local key stores, and merged
//! observability.
//!
//! The program is compiled ONCE ([`compiler::compile`]) and the resulting
//! [`CompiledPlan`] is shared by every shard's workers
//! ([`Coordinator::start_with_plan_store`]), so all shards execute — and
//! `arch::sim` costs — the identical artifact. Keys are resolved per
//! *session* through one [`KeyStore`] per shard: the compat constructors
//! wrap a single `Arc<ServerKeys>` in [`StaticKeys`] (replicated or
//! per-shard), while [`Cluster::start_with_store_factory`] installs
//! multi-tenant stores (e.g. `tenant::SeededTenantStore`) whose cached
//! key material lives shard-locally — which is exactly why consistent-hash
//! placement pins a session to one shard: its keys stay warm there.
//!
//! Admission is permit-based: [`Cluster::submit`] atomically claims one of
//! `queue_depth` slots and hands the permit to the returned
//! [`ClusterResponse`]; the slot is released when the client drops the
//! handle (normally right after `recv`) — or immediately when a deadline
//! expires, so slow shards cannot leak queue capacity. At depth, `submit`
//! fails fast with [`ClusterError::ClusterFull`] instead of queueing
//! unboundedly — callers shed load or retry after draining, exactly the
//! backpressure a front door needs at millions-of-users scale.
//!
//! **QoS admission** ([`ClusterOptions::qos`]). The direct permit path is
//! first-come-first-served: one tenant submitting faster than the shards
//! drain occupies every permit, and everyone else queues behind its
//! backlog. With QoS enabled, `submit` instead (1) charges the tenant's
//! token bucket — an empty bucket fails typed with
//! [`ClusterError::Throttled`]; (2) enqueues the request on the tenant's
//! own bounded FIFO lane inside a weighted deficit-round-robin queue
//! ([`crate::traffic::qos::DrrQueue`]) — a full lane fails typed with
//! [`ClusterError::TenantQueueFull`], stalling only that tenant; and
//! (3) a dispatcher thread drains lanes in weighted-fair order, claiming
//! a shared admission permit per dispatch, so the permit bound still
//! holds but its *order* is fair rather than FIFO. The returned
//! [`ClusterResponse`] resolves to a shard ticket once dispatched; a
//! handle dropped while still queued (client disconnect) marks its job
//! cancelled so the dispatcher discards it — the lane slot and permit
//! can never leak. With `qos: None` none of this machinery is even
//! constructed: admission is bit-for-bit the original direct path.
//!
//! **Supervision.** A supervisor thread watches the shards: every failed
//! batch reports each of its requests on a failure channel, the router
//! tracks per-shard health (consecutive failures + queue age), a shard
//! that crosses the failure threshold is quarantined and restarted *with
//! the same key store* (warm keys, no regeneration), and each failed
//! request is re-dispatched to a healthy shard up to
//! [`SupervisorOptions::max_retries`] times — safe because plan execution
//! is deterministic and a request only ever fails *before* producing a
//! response. Requests that exhaust their retries fail their ticket with a
//! typed error; nothing ever hangs.
//!
//! [`Cluster::reshard`] changes the shard count live: admissions pause
//! (the call holds `&mut self`), every in-flight request drains through
//! its original shard, the consistent-hash ring is rebuilt, and
//! shard-local key-cache entries whose ring ownership moved are migrated
//! — evicted from the old owner's store and registered (same `Arc`, no
//! regeneration) into the new owner's.

use std::collections::HashMap;
use std::fmt;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Condvar, Mutex, PoisonError, RwLock};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::router::{HealthState, PlacementPolicy, Router, DEFAULT_DOWN_AFTER};
use crate::compiler::{self, CompiledPlan};
use crate::coordinator::server::{FailedRequest, FailureSink};
use crate::coordinator::{
    Coordinator, CoordinatorOptions, MetricsSnapshot, RequestError, SubmitError, Ticket,
};
use crate::ir::Program;
use crate::obs;
use crate::tenant::{KeyStore, KeyStoreStats, RegisterError, SessionId, StaticKeys};
use crate::tfhe::{LweCiphertext, ServerKeys};
use crate::traffic::qos::{DrrQueue, QosOptions, TokenBucket};

/// Builds the shard-local [`KeyStore`] for a shard index — how the
/// cluster creates stores at startup and for shards added by
/// [`Cluster::reshard`]. Factories for seeded tenant stores typically
/// ignore the index (every shard derives the same per-session bits from
/// the master seed); clusters built over fixed per-shard key vectors
/// cannot grow past their length ([`ReshardError::FixedStores`]).
pub type StoreFactory = Arc<dyn Fn(usize) -> Arc<dyn KeyStore> + Send + Sync>;

#[derive(Debug, Clone)]
pub struct ClusterOptions {
    /// Number of coordinator shards (each with its own worker pool).
    pub shards: usize,
    /// How the router places requests onto shards.
    pub policy: PlacementPolicy,
    /// Cluster-wide admission bound: maximum outstanding responses before
    /// [`Cluster::submit`] returns [`ClusterError::ClusterFull`]. `None`
    /// admits without limit.
    pub queue_depth: Option<usize>,
    /// Per-shard coordinator configuration (workers, batcher, backend,
    /// optional per-shard `max_queue_depth`).
    pub coordinator: CoordinatorOptions,
    /// QoS admission front: per-tenant token-bucket rate limits and a
    /// weighted deficit-round-robin fair queue replacing direct
    /// first-come-first-served permit admission. `None` keeps the
    /// original direct path bit-for-bit (no dispatcher thread, no queue
    /// state is even constructed).
    pub qos: Option<QosOptions>,
}

impl Default for ClusterOptions {
    fn default() -> Self {
        Self {
            shards: 2,
            policy: PlacementPolicy::RoundRobin,
            queue_depth: None,
            coordinator: CoordinatorOptions::default(),
            qos: None,
        }
    }
}

/// Fault-tolerance knobs for the cluster supervisor (separate from
/// [`ClusterOptions`] so existing construction sites keep compiling; the
/// defaults apply unless a `*_supervised` constructor overrides them).
#[derive(Debug, Clone)]
pub struct SupervisorOptions {
    /// Re-dispatches per failed request before its ticket fails with
    /// [`RequestError::ExecFailed`].
    pub max_retries: u32,
    /// Consecutive batch failures at which a shard is quarantined
    /// (`Down`, skipped by placement) and restarted.
    pub restart_after_failures: u32,
    /// Queue-age threshold: a shard with in-flight requests but no
    /// worker progress for this long is marked `Degraded`, and `Down` at
    /// twice this (recomputed every poll tick — the signal clears itself
    /// when the shard moves again; stalled shards are routed around, not
    /// restarted, since joining stuck workers could hang the supervisor).
    pub stall_after: Duration,
    /// Supervisor poll interval (failure-event wait + stall sweep).
    pub poll: Duration,
}

impl Default for SupervisorOptions {
    fn default() -> Self {
        Self {
            max_retries: 2,
            restart_after_failures: DEFAULT_DOWN_AFTER,
            stall_after: Duration::from_millis(500),
            poll: Duration::from_millis(20),
        }
    }
}

/// Error returned by [`Cluster::submit`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ClusterError {
    /// The shared admission queue is at `queue_depth` — shed load.
    ClusterFull,
    /// The routed shard's own `max_queue_depth` bound fired.
    ShardFull,
    /// The cluster (or every candidate shard) has shut down.
    Stopped,
    /// No candidate shard could resolve the session's keys.
    ResolveFailed,
    /// QoS: the tenant's token bucket is empty — its rate limit is
    /// exceeded; retry after the bucket refills. Only this tenant is
    /// affected.
    Throttled,
    /// QoS: the tenant's lane in the fair admission queue is at its
    /// depth bound — this tenant must shed load; other tenants' lanes
    /// are unaffected.
    TenantQueueFull,
}

impl fmt::Display for ClusterError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ClusterError::ClusterFull => f.write_str("cluster admission queue full"),
            ClusterError::ShardFull => f.write_str("routed shard queue full"),
            ClusterError::Stopped => f.write_str("cluster stopped"),
            ClusterError::ResolveFailed => f.write_str("session key resolution failed"),
            ClusterError::Throttled => f.write_str("tenant rate limit exceeded"),
            ClusterError::TenantQueueFull => f.write_str("tenant admission queue full"),
        }
    }
}

impl std::error::Error for ClusterError {}

/// Error returned by [`Cluster::reshard`]. The cluster is untouched when
/// this is returned: still accepting, topology unchanged.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReshardError {
    /// Growing past the fixed per-shard keys/stores provided at
    /// construction ([`Cluster::start_with_shard_keys`] /
    /// [`Cluster::start_with_stores`]): those constructors cannot mint
    /// material for new shards — build with
    /// [`Cluster::start_with_store_factory`] to grow freely.
    FixedStores { provided: usize, requested: usize },
}

impl fmt::Display for ReshardError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ReshardError::FixedStores { provided, requested } => write!(
                f,
                "cannot reshard to {requested} shards: only {provided} fixed key \
                 stores were provided at construction (growing needs a store factory)"
            ),
        }
    }
}

impl std::error::Error for ReshardError {}

/// One slot in the shared admission queue; releases on drop.
#[derive(Debug)]
struct AdmissionPermit {
    admitted: Arc<AtomicUsize>,
}

impl AdmissionPermit {
    fn acquire(
        admitted: &Arc<AtomicUsize>,
        depth: Option<usize>,
    ) -> Result<Self, ClusterError> {
        if !crate::coordinator::server::try_claim_slot(admitted, depth) {
            return Err(ClusterError::ClusterFull);
        }
        Ok(Self { admitted: admitted.clone() })
    }
}

impl Drop for AdmissionPermit {
    fn drop(&mut self) {
        self.admitted.fetch_sub(1, Ordering::SeqCst);
    }
}

/// What the QoS dispatcher hands back once it routed a queued request
/// into a shard: the shard ticket plus the admission permit it claimed.
#[derive(Debug)]
struct Dispatched {
    ticket: Ticket,
    shard: usize,
    permit: AdmissionPermit,
}

/// Progress of one submitted request through its lifecycle.
#[derive(Debug)]
enum ResponseState {
    /// Dispatched to a shard; the ticket delivers the terminal.
    Ready(Ticket),
    /// Waiting in the fair admission queue for the dispatcher.
    Queued { rx: Receiver<Result<Dispatched, ClusterError>>, deadline: Option<Instant> },
    /// Terminated before a shard ever saw it (queue-time deadline
    /// expiry, shutdown drain, or a dispatch-time routing failure).
    Failed(RequestError),
}

/// A pending response plus its admission slot. The slot frees when this
/// handle is dropped, so a client that holds N handles occupies N of the
/// cluster's `queue_depth` — backpressure is deterministic, independent of
/// worker timing. A deadline expiry ([`RequestError::RequestTimeout`])
/// releases the slot immediately, so a slow shard cannot leak queue
/// capacity through abandoned waits.
///
/// On the QoS path the handle starts [`ResponseState::Queued`]: the
/// permit arrives with the dispatch result, and dropping the handle
/// before dispatch cancels the queued job — the dispatcher discards it
/// at the lane head instead of routing work nobody will collect.
#[derive(Debug)]
pub struct ClusterResponse {
    state: Mutex<ResponseState>,
    /// Which shard served this request (useful for affinity checks).
    /// Meaningful on the direct (QoS-off) path; on the fair-queue path
    /// the shard is only known after dispatch — use
    /// [`Self::served_by`], which covers both.
    pub shard: usize,
    /// Shard resolved at dispatch time on the QoS path (`usize::MAX`
    /// until known).
    dispatched_shard: AtomicUsize,
    permit: Mutex<Option<AdmissionPermit>>,
    /// QoS path only: abandonment flag shared with the queued job.
    cancel: Option<Arc<AtomicBool>>,
}

/// Map a dispatch-time cluster error into the typed request terminal the
/// already-issued response handle delivers.
fn dispatch_error(e: ClusterError) -> RequestError {
    match e {
        ClusterError::Stopped => RequestError::ShardLost,
        ClusterError::ResolveFailed => RequestError::ResolveFailed {
            reason: "no candidate shard could resolve the session's keys".into(),
        },
        other => RequestError::ExecFailed { reason: format!("dispatch failed: {other}") },
    }
}

impl ClusterResponse {
    /// Wait for this request to terminate: output ciphertexts or a typed
    /// [`RequestError`] — never a hang.
    pub fn wait(&self) -> Result<Vec<LweCiphertext>, RequestError> {
        let mut st = self.state.lock().unwrap_or_else(PoisonError::into_inner);
        let resolved = match &mut *st {
            ResponseState::Queued { rx, deadline } => {
                let outcome = match deadline {
                    None => rx.recv().map_err(|_| false),
                    Some(d) => {
                        match rx.recv_timeout(d.saturating_duration_since(Instant::now())) {
                            Ok(o) => Ok(o),
                            // `true`: queue-time deadline expiry.
                            Err(RecvTimeoutError::Timeout) => Err(true),
                            // `false`: dispatcher gone without answering.
                            Err(RecvTimeoutError::Disconnected) => Err(false),
                        }
                    }
                };
                Some(match outcome {
                    Ok(Ok(d)) => {
                        self.dispatched_shard.store(d.shard, Ordering::SeqCst);
                        *self.permit.lock().unwrap_or_else(PoisonError::into_inner) =
                            Some(d.permit);
                        ResponseState::Ready(d.ticket)
                    }
                    Ok(Err(e)) => ResponseState::Failed(dispatch_error(e)),
                    Err(true) => {
                        // Tell the dispatcher to discard the job at the
                        // lane head; the lane slot frees without a
                        // dispatch ever claiming a permit.
                        if let Some(c) = &self.cancel {
                            c.store(true, Ordering::SeqCst);
                        }
                        ResponseState::Failed(RequestError::RequestTimeout)
                    }
                    Err(false) => ResponseState::Failed(RequestError::ShardLost),
                })
            }
            _ => None,
        };
        if let Some(next) = resolved {
            *st = next;
        }
        let r = match &*st {
            ResponseState::Ready(t) => t.wait(),
            ResponseState::Failed(e) => Err(e.clone()),
            ResponseState::Queued { .. } => unreachable!("queued state resolved above"),
        };
        drop(st);
        if matches!(r, Err(RequestError::RequestTimeout)) {
            // The request may still be executing server-side, but its
            // admission slot frees NOW: deadlines bound queue occupancy.
            self.permit.lock().unwrap_or_else(PoisonError::into_inner).take();
        }
        r
    }

    /// Alias for [`Self::wait`].
    pub fn recv(&self) -> Result<Vec<LweCiphertext>, RequestError> {
        self.wait()
    }

    /// The shard that served (or is serving) this request, on either
    /// admission path. `None` while a QoS-queued request has not been
    /// dispatched yet.
    pub fn served_by(&self) -> Option<usize> {
        if self.cancel.is_none() {
            return Some(self.shard);
        }
        match self.dispatched_shard.load(Ordering::SeqCst) {
            usize::MAX => None,
            s => Some(s),
        }
    }
}

impl Drop for ClusterResponse {
    fn drop(&mut self) {
        // QoS path, client-disconnect semantics: a handle dropped while
        // its job is still queued marks the job cancelled; the
        // dispatcher discards it at the lane head, freeing the tenant's
        // queue slot without claiming a permit. (A job dispatched
        // despite the race sends into this dropped handle's channel;
        // the failed send drops the Dispatched — and its permit — on
        // the spot.)
        if let Some(c) = &self.cancel {
            c.store(true, Ordering::SeqCst);
        }
    }
}

/// What one [`Cluster::reshard`] call did.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ReshardReport {
    pub old_shards: usize,
    pub new_shards: usize,
    /// Key-cache entries resident across all shard stores before the
    /// reshard.
    pub resident_before: usize,
    /// Entries whose ring ownership moved and that were re-registered
    /// into their new owner's store (consistent-hash policy; other
    /// policies migrate only entries orphaned by removed shards).
    pub migrated: usize,
    /// Entries resident across all shard stores after migration. Can be
    /// below `resident_before` on a shrink: target stores' capacity
    /// bounds bind during migration too, so a full target LRU-displaces
    /// (counted in its eviction stats) and the displaced tenants
    /// regenerate on next touch — *cache* residency never exceeds
    /// `capacity x shards` no matter how the topology moves. (Evicted
    /// material is freed once its last handle drops: each worker pins
    /// the key set it last executed and in-flight requests pin theirs,
    /// so peak key memory is `capacity x shards` plus up to one
    /// transient set per worker/in-flight handle.)
    pub resident_after: usize,
}

/// State shared between client handles and the supervisor thread. Lock
/// order (when several are held): `shards` -> `stores` -> `router`.
struct Shared {
    shards: RwLock<Vec<Coordinator>>,
    stores: RwLock<Vec<Arc<dyn KeyStore>>>,
    router: RwLock<Router>,
    /// Metrics of coordinators drained by reshards and restarts
    /// (request-path counters only — surviving stores keep reporting
    /// their own cumulative counters through the live shards).
    retired: Mutex<Vec<MetricsSnapshot>>,
    /// Topology generation, bumped by [`Cluster::reshard`]. Failure
    /// events from an older generation reference shard ids that may no
    /// longer exist; they are failed terminally (typed), never retried
    /// against the new topology and never dropped.
    generation: AtomicU64,
    retries: AtomicU64,
    redirects: AtomicU64,
    restarts: AtomicU64,
    /// Client-uploaded key material, by session. The source of truth for
    /// re-broadcast: [`Cluster::register_session`] pins uploads into
    /// EVERY shard store (non-affinity routers may send the next request
    /// anywhere), and [`Cluster::reshard`] replays this map so
    /// factory-minted new shards — which start with empty stores — hold
    /// the uploads too. Uploaded keys are not derivable server-side;
    /// without the replay a reshard would reintroduce the
    /// silent-wrong-keys bug on grown clusters.
    uploaded: Mutex<HashMap<SessionId, Arc<ServerKeys>>>,
}

fn read<T>(l: &RwLock<T>) -> std::sync::RwLockReadGuard<'_, T> {
    l.read().unwrap_or_else(PoisonError::into_inner)
}

fn write<T>(l: &RwLock<T>) -> std::sync::RwLockWriteGuard<'_, T> {
    l.write().unwrap_or_else(PoisonError::into_inner)
}

/// One request waiting in the fair admission queue.
struct QueuedJob {
    session: SessionId,
    inputs: Vec<LweCiphertext>,
    /// Absolute deadline — queueing time counts against the request's
    /// budget; the dispatcher hands the *remaining* time to the shard.
    deadline: Option<Instant>,
    /// Set by the response handle (drop or queue-time timeout): the
    /// dispatcher discards the job instead of routing it.
    cancel: Arc<AtomicBool>,
    respond: Sender<Result<Dispatched, ClusterError>>,
}

/// QoS admission state shared between submitters and the dispatcher
/// thread.
struct QosShared {
    opts: QosOptions,
    /// Weighted-fair queue of pending jobs; `cv` is signaled on push and
    /// on shutdown.
    queue: Mutex<DrrQueue<QueuedJob>>,
    cv: Condvar,
    /// Set (under the queue lock) by [`Cluster::shutdown`]; the
    /// dispatcher drains remaining jobs typed and exits.
    stopped: AtomicBool,
    /// Per-tenant token buckets, lazily created on first submit.
    buckets: Mutex<HashMap<u64, TokenBucket>>,
    /// Requests rejected with [`ClusterError::Throttled`].
    throttled: AtomicU64,
    /// Requests rejected with [`ClusterError::TenantQueueFull`].
    rejections: AtomicU64,
}

/// N replicated serving engines behind one admission-controlled router,
/// each shard resolving session keys through its own shard-local store,
/// watched by a supervisor thread that retries failed requests and
/// restarts failed shards.
pub struct Cluster {
    shared: Arc<Shared>,
    factory: StoreFactory,
    policy: PlacementPolicy,
    coordinator_opts: CoordinatorOptions,
    supervision: SupervisorOptions,
    admitted: Arc<AtomicUsize>,
    queue_depth: Option<usize>,
    plan: Arc<CompiledPlan>,
    accepting: bool,
    /// `Some(n)` when construction provided exactly `n` fixed stores:
    /// [`Self::reshard`] cannot grow past it.
    store_limit: Option<usize>,
    /// Final counters of stores dropped by past shrinks.
    retired_key_stats: KeyStoreStats,
    failure_tx: Sender<FailedRequest>,
    supervisor: Option<JoinHandle<()>>,
    stop: Arc<AtomicBool>,
    /// QoS admission state (`None` = direct path, no dispatcher).
    qos: Option<Arc<QosShared>>,
    dispatcher: Option<JoinHandle<()>>,
}

impl Cluster {
    /// Start with replicated keys: every shard serves under the same
    /// `ServerKeys` (one [`StaticKeys`] wrapper per shard — no key
    /// material is copied, and per-shard store counters stay disjoint).
    pub fn start(program: Program, keys: Arc<ServerKeys>, opts: ClusterOptions) -> Self {
        let factory: StoreFactory =
            Arc::new(move |_shard| Arc::new(StaticKeys::new(keys.clone())) as Arc<dyn KeyStore>);
        Self::start_with_store_factory(program, factory, opts)
    }

    /// Start with per-shard keys (all generated for the same parameter
    /// set); `shard_keys.len()` overrides `opts.shards`. Growing past the
    /// provided keys via [`Self::reshard`] returns
    /// [`ReshardError::FixedStores`] — fixed per-shard key vectors cannot
    /// invent material for new shards.
    pub fn start_with_shard_keys(
        program: Program,
        shard_keys: Vec<Arc<ServerKeys>>,
        opts: ClusterOptions,
    ) -> Self {
        assert!(!shard_keys.is_empty(), "cluster needs at least one shard");
        let mut opts = opts;
        opts.shards = shard_keys.len();
        let limit = shard_keys.len();
        let factory: StoreFactory = Arc::new(move |shard| {
            // In range by construction: reshard gates growth on the store
            // limit before ever calling the factory.
            let keys = shard_keys
                .get(shard)
                .expect("shard index within the fixed key vector (gated by store_limit)")
                .clone();
            Arc::new(StaticKeys::new(keys)) as Arc<dyn KeyStore>
        });
        Self::start_inner(program, factory, opts, SupervisorOptions::default(), Some(limit))
    }

    /// Start with explicit shard-local stores (`stores.len()` overrides
    /// `opts.shards`). Growing past the provided stores via
    /// [`Self::reshard`] returns [`ReshardError::FixedStores`]; use
    /// [`Self::start_with_store_factory`] when the cluster must be able
    /// to mint stores for new shards.
    pub fn start_with_stores(
        program: Program,
        stores: Vec<Arc<dyn KeyStore>>,
        opts: ClusterOptions,
    ) -> Self {
        assert!(!stores.is_empty(), "cluster needs at least one shard");
        let mut opts = opts;
        opts.shards = stores.len();
        let limit = stores.len();
        let factory: StoreFactory = Arc::new(move |shard| {
            stores
                .get(shard)
                .expect("shard index within the fixed store vector (gated by store_limit)")
                .clone()
        });
        Self::start_inner(program, factory, opts, SupervisorOptions::default(), Some(limit))
    }

    /// The primary session-keyed constructor: `factory(i)` builds shard
    /// `i`'s local [`KeyStore`] — at startup for `0..opts.shards` and
    /// again for any shard [`Self::reshard`] adds later.
    pub fn start_with_store_factory(
        program: Program,
        factory: StoreFactory,
        opts: ClusterOptions,
    ) -> Self {
        Self::start_inner(program, factory, opts, SupervisorOptions::default(), None)
    }

    /// [`Self::start_with_store_factory`] with explicit fault-tolerance
    /// knobs (retry budget, quarantine threshold, stall windows).
    pub fn start_with_store_factory_supervised(
        program: Program,
        factory: StoreFactory,
        opts: ClusterOptions,
        supervision: SupervisorOptions,
    ) -> Self {
        Self::start_inner(program, factory, opts, supervision, None)
    }

    fn start_inner(
        program: Program,
        factory: StoreFactory,
        opts: ClusterOptions,
        supervision: SupervisorOptions,
        store_limit: Option<usize>,
    ) -> Self {
        let shards = opts.shards;
        assert!(shards >= 1, "cluster needs at least one shard");
        assert_ne!(
            opts.queue_depth,
            Some(0),
            "queue_depth 0 would reject every request; use None for unbounded"
        );
        let mut stores: Vec<Arc<dyn KeyStore>> = Vec::with_capacity(shards);
        for i in 0..shards {
            stores.push(factory(i));
        }
        let params = stores[0].params().clone();
        assert!(
            stores.iter().all(|s| s.params().name == params.name),
            "all shards must use one parameter set"
        );
        // Compile once; every shard executes (and `arch::sim` costs) the
        // same artifact.
        let plan = Arc::new(compiler::compile(&program, &params, opts.coordinator.plan_capacity));
        let (failure_tx, failure_rx) = channel::<FailedRequest>();
        let shard_coords: Vec<Coordinator> = stores
            .iter()
            .enumerate()
            .map(|(i, store)| {
                Coordinator::start_supervised(
                    plan.clone(),
                    store.clone(),
                    opts.coordinator.clone(),
                    Some(FailureSink { shard: i, generation: 0, tx: failure_tx.clone() }),
                )
            })
            .collect();
        let router =
            Router::new_with_health(opts.policy, shards, supervision.restart_after_failures);
        let shared = Arc::new(Shared {
            shards: RwLock::new(shard_coords),
            stores: RwLock::new(stores),
            router: RwLock::new(router),
            retired: Mutex::new(Vec::new()),
            generation: AtomicU64::new(0),
            retries: AtomicU64::new(0),
            redirects: AtomicU64::new(0),
            restarts: AtomicU64::new(0),
            uploaded: Mutex::new(HashMap::new()),
        });
        let stop = Arc::new(AtomicBool::new(false));
        let supervisor = {
            let shared = shared.clone();
            let plan = plan.clone();
            let coord_opts = opts.coordinator.clone();
            let failure_tx = failure_tx.clone();
            let sup = supervision.clone();
            let stop = stop.clone();
            std::thread::spawn(move || {
                supervisor_loop(shared, failure_rx, plan, coord_opts, failure_tx, sup, stop)
            })
        };
        let admitted = Arc::new(AtomicUsize::new(0));
        let qos = opts.qos.map(|qopts| {
            qopts.validate();
            let mut queue = DrrQueue::new(qopts.quantum, qopts.tenant_queue_depth);
            for (&tenant, &w) in &qopts.weights {
                queue.set_weight(tenant, w);
            }
            Arc::new(QosShared {
                opts: qopts,
                queue: Mutex::new(queue),
                cv: Condvar::new(),
                stopped: AtomicBool::new(false),
                buckets: Mutex::new(HashMap::new()),
                throttled: AtomicU64::new(0),
                rejections: AtomicU64::new(0),
            })
        });
        let dispatcher = qos.as_ref().map(|q| {
            let shared = shared.clone();
            let q = q.clone();
            let admitted = admitted.clone();
            let depth = opts.queue_depth;
            std::thread::spawn(move || dispatcher_loop(shared, q, admitted, depth))
        });
        Self {
            shared,
            factory,
            policy: opts.policy,
            coordinator_opts: opts.coordinator,
            supervision,
            admitted,
            queue_depth: opts.queue_depth,
            plan,
            accepting: true,
            store_limit,
            retired_key_stats: KeyStoreStats::default(),
            failure_tx,
            supervisor: Some(supervisor),
            stop,
            qos,
            dispatcher,
        }
    }

    /// The compiled plan every shard executes.
    pub fn plan(&self) -> &CompiledPlan {
        &self.plan
    }

    pub fn shard_count(&self) -> usize {
        read(&self.shared.shards).len()
    }

    pub fn policy(&self) -> PlacementPolicy {
        self.policy
    }

    /// The shard-local key stores, indexed by shard id.
    pub fn stores(&self) -> Vec<Arc<dyn KeyStore>> {
        read(&self.shared.stores).clone()
    }

    /// Current supervisor view of every shard's health, indexed by shard
    /// id.
    pub fn shard_healths(&self) -> Vec<HealthState> {
        read(&self.shared.router).healths()
    }

    /// Currently admitted (undropped) responses across the cluster.
    pub fn outstanding(&self) -> usize {
        self.admitted.load(Ordering::SeqCst)
    }

    /// Requests currently inside shard pipelines (submitted, not yet
    /// completed). This is the autoscaler's backlog signal: unlike
    /// [`Self::outstanding`] it excludes responses already delivered but
    /// not yet dropped by slow readers.
    pub fn inflight(&self) -> usize {
        read(&self.shared.shards).iter().map(|c| c.inflight.load(Ordering::SeqCst)).sum()
    }

    /// Requests waiting in the fair admission queue (0 when QoS is off).
    pub fn fair_queue_len(&self) -> usize {
        self.qos.as_ref().map_or(0, |q| {
            q.queue.lock().unwrap_or_else(PoisonError::into_inner).len()
        })
    }

    /// A shareable handle to the compiled plan (the autoscaler wrapper
    /// holds the cluster behind a lock, so it cannot hand out
    /// [`Self::plan`]'s borrow across the guard).
    pub fn plan_handle(&self) -> Arc<CompiledPlan> {
        self.plan.clone()
    }

    /// Whether every shard store can hold client-uploaded key material.
    /// The wire protocol's key-upload handler checks this at admission so
    /// an upload against a single-key ([`StaticKeys`]) cluster is
    /// rejected typed instead of reaching `StaticKeys::register`'s panic.
    pub fn supports_register(&self) -> bool {
        read(&self.shared.stores).iter().all(|s| s.supports_register())
    }

    /// Install client-uploaded keys for `session` on **every** shard
    /// store, pinned against eviction, and remember them for replay on
    /// [`Self::reshard`].
    ///
    /// Broadcast is the correctness fix for non-affinity placement: under
    /// round-robin or least-outstanding the next request for the session
    /// can land on any shard, and a shard without the uploaded keys would
    /// silently re-derive *different* bits from its master seed — every
    /// result garbage to the client. All-or-nothing: every store is
    /// validated (capability + parameter set) before any is touched.
    /// Returns the number of shard stores now holding the keys.
    pub fn register_session(
        &self,
        session: impl Into<SessionId>,
        keys: Arc<ServerKeys>,
    ) -> Result<usize, RegisterError> {
        let session = session.into();
        let stores = read(&self.shared.stores);
        for store in stores.iter() {
            if !store.supports_register() {
                return Err(RegisterError::Unsupported);
            }
            if store.params().name != keys.params.name {
                return Err(RegisterError::ParamMismatch {
                    expected: store.params().name,
                    got: keys.params.name,
                });
            }
        }
        for store in stores.iter() {
            store.register_uploaded(session, keys.clone())?;
        }
        self.shared
            .uploaded
            .lock()
            .unwrap_or_else(PoisonError::into_inner)
            .insert(session, keys);
        Ok(stores.len())
    }

    /// Admit, route, and submit one encrypted query for `session` (plain
    /// `u64` client ids convert). The inputs are consumed either way; a
    /// single-submitter client that wants lossless backpressure should
    /// drain a pending response while [`Self::outstanding`] sits at the
    /// queue depth (as the drivers do) rather than bounce off
    /// [`ClusterError::ClusterFull`].
    pub fn submit(
        &self,
        session: impl Into<SessionId>,
        inputs: Vec<LweCiphertext>,
    ) -> Result<ClusterResponse, ClusterError> {
        self.submit_inner(session.into(), inputs, None)
    }

    /// [`Self::submit`] with a per-request deadline: the response's
    /// `wait()` yields [`RequestError::RequestTimeout`] once `deadline`
    /// elapses, releasing the admission slot immediately.
    pub fn submit_with_deadline(
        &self,
        session: impl Into<SessionId>,
        inputs: Vec<LweCiphertext>,
        deadline: Duration,
    ) -> Result<ClusterResponse, ClusterError> {
        self.submit_inner(session.into(), inputs, Some(deadline))
    }

    fn submit_inner(
        &self,
        session: SessionId,
        inputs: Vec<LweCiphertext>,
        deadline: Option<Duration>,
    ) -> Result<ClusterResponse, ClusterError> {
        if !self.accepting {
            return Err(ClusterError::Stopped);
        }
        if let Some(qos) = &self.qos {
            return self.submit_fair(qos, session, inputs, deadline);
        }
        // The permit is dropped (slot released) on any error path below.
        let permit = AdmissionPermit::acquire(&self.admitted, self.queue_depth)?;
        let (ticket, shard) = route_submit(&self.shared, session, inputs, deadline)?;
        Ok(ClusterResponse {
            state: Mutex::new(ResponseState::Ready(ticket)),
            shard,
            dispatched_shard: AtomicUsize::new(shard),
            permit: Mutex::new(Some(permit)),
            cancel: None,
        })
    }

    /// QoS admission: charge the tenant's token bucket, then queue the
    /// request on its fair-queue lane for the dispatcher. Both rejections
    /// are typed and tenant-scoped — a hot tenant exhausts its *own*
    /// bucket and lane, never the shared permit pool.
    fn submit_fair(
        &self,
        qos: &QosShared,
        session: SessionId,
        inputs: Vec<LweCiphertext>,
        deadline: Option<Duration>,
    ) -> Result<ClusterResponse, ClusterError> {
        if let Some(spec) = &qos.opts.bucket {
            let now = Instant::now();
            let mut buckets = qos.buckets.lock().unwrap_or_else(PoisonError::into_inner);
            let bucket =
                buckets.entry(session.0).or_insert_with(|| TokenBucket::new(spec.clone(), now));
            if !bucket.try_take(now) {
                qos.throttled.fetch_add(1, Ordering::SeqCst);
                return Err(ClusterError::Throttled);
            }
        }
        let deadline = deadline.map(|d| Instant::now() + d);
        let (respond, rx) = channel();
        let cancel = Arc::new(AtomicBool::new(false));
        let job = QueuedJob { session, inputs, deadline, cancel: cancel.clone(), respond };
        {
            let mut q = qos.queue.lock().unwrap_or_else(PoisonError::into_inner);
            if qos.stopped.load(Ordering::SeqCst) {
                return Err(ClusterError::Stopped);
            }
            if q.push(session.0, job).is_err() {
                qos.rejections.fetch_add(1, Ordering::SeqCst);
                return Err(ClusterError::TenantQueueFull);
            }
            qos.cv.notify_one();
        }
        Ok(ClusterResponse {
            state: Mutex::new(ResponseState::Queued { rx, deadline }),
            shard: usize::MAX,
            dispatched_shard: AtomicUsize::new(usize::MAX),
            permit: Mutex::new(None),
            cancel: Some(cancel),
        })
    }

    /// Per-shard metrics (request-path counters + the shard store's key
    /// counters), indexed by shard id.
    pub fn shard_snapshots(&self) -> Vec<MetricsSnapshot> {
        read(&self.shared.shards).iter().map(|c| c.snapshot()).collect()
    }

    /// Aggregate cluster metrics: counters summed (including per-tenant
    /// request counts and key-cache counters), percentiles recomputed
    /// over the concatenated samples ([`MetricsSnapshot::merge`]).
    /// Includes shards drained by past [`Self::reshard`] calls and
    /// supervisor restarts, so totals are lifetime totals: every admitted
    /// request appears exactly once. The cluster-level recovery counters
    /// (`request_retries`, `request_redirects`, `shard_restarts`) are
    /// filled here — per-shard snapshots report them as zero.
    pub fn snapshot(&self) -> MetricsSnapshot {
        let mut all =
            self.shared.retired.lock().unwrap_or_else(PoisonError::into_inner).clone();
        all.extend(self.shard_snapshots());
        let mut merged = MetricsSnapshot::merge(&all);
        merged.key_hits += self.retired_key_stats.hits;
        merged.key_misses += self.retired_key_stats.misses;
        merged.key_evictions += self.retired_key_stats.evictions;
        merged.key_regenerations += self.retired_key_stats.regenerations;
        merged.request_retries += self.shared.retries.load(Ordering::SeqCst);
        merged.request_redirects += self.shared.redirects.load(Ordering::SeqCst);
        merged.shard_restarts += self.shared.restarts.load(Ordering::SeqCst);
        if let Some(qos) = &self.qos {
            merged.qos_throttled += qos.throttled.load(Ordering::SeqCst);
            merged.qos_queue_rejections += qos.rejections.load(Ordering::SeqCst);
        }
        merged
    }

    /// Live reshard to `new_shards` coordinator shards.
    ///
    /// Holding `&mut self` guarantees no concurrent [`Self::submit`]:
    /// admissions are paused for the duration. Every already-admitted
    /// request drains through its original shard (the per-shard shutdown
    /// flushes batchers and joins workers), so nothing is lost and
    /// nothing re-executes; undropped [`ClusterResponse`] handles keep
    /// their admission slots and deliver normally.
    ///
    /// Shard-local stores survive: shard `i < min(old, new)` keeps its
    /// store, new shards get `factory(i)` stores, and removed shards'
    /// stores are dropped after migration. Under the consistent-hash
    /// policy, every resident cache entry whose ring ownership changed is
    /// migrated (evict + register, preserving the `Arc` — no
    /// regeneration); the ring keeps most assignments stable, so only the
    /// ring-predicted fraction moves. Under other policies sessions have
    /// no shard affinity, so only entries orphaned by removed shards are
    /// rehomed (`session % new_shards`). Target capacity still binds: a
    /// shrink that funnels more entries into a store than it can hold
    /// LRU-displaces the excess (see [`ReshardReport::resident_after`]) —
    /// the displaced tenants regenerate on next touch rather than the
    /// cluster exceeding its residency bound.
    ///
    /// Fails with [`ReshardError::FixedStores`] — before touching any
    /// shard — when growth would exceed the fixed stores provided at
    /// construction.
    pub fn reshard(&mut self, new_shards: usize) -> Result<ReshardReport, ReshardError> {
        assert!(new_shards >= 1, "cluster needs at least one shard");
        if let Some(limit) = self.store_limit {
            if new_shards > limit {
                return Err(ReshardError::FixedStores {
                    provided: limit,
                    requested: new_shards,
                });
            }
        }
        self.accepting = false;
        let mut shards = write(&self.shared.shards);
        let mut stores_guard = write(&self.shared.stores);
        let old_shards = shards.len();

        // Drain: every admitted request is answered by its original
        // shard before any topology change.
        for shard in shards.iter_mut() {
            shard.shutdown();
        }
        self.shared
            .retired
            .lock()
            .unwrap_or_else(PoisonError::into_inner)
            .extend(shards.iter().map(|c| c.metrics.snapshot()));
        shards.clear();

        // New ring first — migration targets are its ownership.
        let router =
            Router::new_with_health(self.policy, new_shards, self.supervision.restart_after_failures);

        // Stores: survivors keep their index, new shards mint via the
        // factory (growth past a fixed store vector was rejected above,
        // so the factory is always called in range).
        let mut stores: Vec<Arc<dyn KeyStore>> = Vec::with_capacity(new_shards);
        for i in 0..new_shards {
            match stores_guard.get(i) {
                Some(s) => stores.push(s.clone()),
                None => stores.push((self.factory)(i)),
            }
        }

        // Migrate cache entries whose ownership moved. Residency is
        // snapshotted per store BEFORE any movement, so an entry migrated
        // into a store processed later is never re-considered (or
        // double-counted).
        let hash_affinity = self.policy == PlacementPolicy::ConsistentHash;
        let resident: Vec<Vec<SessionId>> =
            stores_guard.iter().map(|s| s.resident()).collect();
        let resident_before: usize = resident.iter().map(Vec::len).sum();
        let mut migrated = 0usize;
        for (i, (store, sessions)) in stores_guard.iter().zip(resident).enumerate() {
            for session in sessions {
                let target = if hash_affinity {
                    router.place(session.0, || {
                        unreachable!("consistent hash never gathers outstanding counts")
                    })
                } else if i >= new_shards {
                    (session.0 % new_shards as u64) as usize
                } else {
                    i // no affinity, shard survives: leave the entry alone
                };
                if target == i {
                    continue;
                }
                let Some(keys) = store.evict(session) else {
                    continue; // raced out from under us; nothing to move
                };
                stores[target].register(session, keys);
                migrated += 1;
            }
        }
        // Replay client uploads: uploaded keys must be resident (and
        // pinned) on EVERY store in the new topology — the migration
        // loop above only preserves one copy, and factory-minted new
        // shards start empty. Same `Arc` everywhere, so no material is
        // copied and batch grouping by pointer identity still holds
        // per-shard. Infallible by construction: `register_session`
        // validated capability and params cluster-wide before recording,
        // and the factory mints stores of the same configuration.
        {
            let uploaded =
                self.shared.uploaded.lock().unwrap_or_else(PoisonError::into_inner);
            for (&session, keys) in uploaded.iter() {
                for store in &stores {
                    store
                        .register_uploaded(session, keys.clone())
                        .expect("uploaded keys were validated cluster-wide at registration");
                }
            }
        }

        // Account stats of stores that are going away (shrink).
        for dropped in stores_guard.iter().skip(new_shards) {
            let st = dropped.stats();
            self.retired_key_stats.hits += st.hits;
            self.retired_key_stats.misses += st.misses;
            self.retired_key_stats.evictions += st.evictions;
            self.retired_key_stats.regenerations += st.regenerations;
        }

        let resident_after: usize = stores.iter().map(|s| s.resident().len()).sum();

        // New topology generation: failure events still in flight from
        // the drained shards reference old shard ids — the supervisor
        // fails them terminally instead of retrying them here.
        let generation = self.shared.generation.fetch_add(1, Ordering::SeqCst) + 1;

        // Relaunch: same compiled plan, new shard set, fresh sinks.
        *shards = stores
            .iter()
            .enumerate()
            .map(|(i, store)| {
                Coordinator::start_supervised(
                    self.plan.clone(),
                    store.clone(),
                    self.coordinator_opts.clone(),
                    Some(FailureSink {
                        shard: i,
                        generation,
                        tx: self.failure_tx.clone(),
                    }),
                )
            })
            .collect();
        *stores_guard = stores;
        *write(&self.shared.router) = router;
        drop(stores_guard);
        drop(shards);
        self.accepting = true;
        Ok(ReshardReport { old_shards, new_shards, resident_before, migrated, resident_after })
    }

    /// Graceful drain: stop admitting, flush every shard's batcher (all
    /// already-admitted requests are answered), join dispatch + worker
    /// threads, then stop the supervisor (failure events raised during
    /// the drain are still retried or failed typed — never dropped
    /// silently). Subsequent [`Self::submit`] calls return
    /// [`ClusterError::Stopped`].
    pub fn shutdown(&mut self) {
        self.accepting = false;
        // Stop the QoS dispatcher first: it drains any still-queued jobs
        // typed ([`ClusterError::Stopped`]) and stops feeding the shards,
        // so the shard drain below sees a quiescent submit path.
        if let Some(qos) = &self.qos {
            {
                let _q = qos.queue.lock().unwrap_or_else(PoisonError::into_inner);
                qos.stopped.store(true, Ordering::SeqCst);
                qos.cv.notify_all();
            }
            if let Some(h) = self.dispatcher.take() {
                let _ = h.join();
            }
        }
        {
            let mut shards = write(&self.shared.shards);
            for shard in shards.iter_mut() {
                shard.shutdown();
            }
        }
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.supervisor.take() {
            let _ = h.join();
        }
    }
}

/// Route one admitted request onto a shard: mint the request's trace id,
/// place by policy, and walk the ring past `Down` shards. Shared by the
/// direct submit path and the QoS dispatcher — both paths produce the
/// identical routing behaviour, so QoS-off serving is bitwise-unchanged.
fn route_submit(
    shared: &Shared,
    session: SessionId,
    mut inputs: Vec<LweCiphertext>,
    deadline: Option<Duration>,
) -> Result<(Ticket, usize), ClusterError> {
    // The request's trace id is minted HERE, at cluster admission:
    // the whole journey — routing, redirects, execution, retries on
    // other shards, the terminal — shares one async span. Shards are
    // entered through `try_submit_traced` so they don't mint again.
    // (On the QoS path this runs at *dispatch*, after the fair queue:
    // pre-dispatch rejections emit no span, keeping begin/end balanced.)
    let trace = obs::next_trace_id();
    obs::trace::async_begin("request", trace);
    obs::trace::instant("admitted", trace);
    // Close the async span on a rejection: no ticket exists to do it.
    let reject = |trace: u64| {
        if trace != 0 {
            obs::trace::instant("rejected", trace);
            obs::trace::async_end("request", trace);
        }
    };
    let shards = read(&shared.shards);
    let router = read(&shared.router);
    // Outstanding counts are gathered lazily — only the
    // least-outstanding policy reads them. Placement already skips
    // `Down` shards.
    let first = router.place(session.0, || {
        shards.iter().map(|c| c.inflight.load(Ordering::SeqCst)).collect()
    });
    let n = shards.len();
    let mut last = ClusterError::Stopped;
    for k in 0..n {
        let shard = (first + k) % n;
        if k > 0 && router.health(shard) == HealthState::Down {
            continue;
        }
        match shards[shard].try_submit_traced(session, inputs, deadline, trace) {
            Ok(ticket) => {
                if k > 0 {
                    shared.redirects.fetch_add(1, Ordering::SeqCst);
                    obs::trace::instant("redirect", trace);
                }
                return Ok((ticket, shard));
            }
            // Shard backpressure is NOT redirected: spilling onto the
            // next shard would defeat the per-shard bound (and change
            // fault-free placement). The caller sheds load.
            Err((SubmitError::QueueFull, _)) => {
                reject(trace);
                return Err(ClusterError::ShardFull);
            }
            Err((e, returned)) => {
                inputs = returned;
                last = match e {
                    SubmitError::Stopped => ClusterError::Stopped,
                    SubmitError::ResolveFailed => ClusterError::ResolveFailed,
                    SubmitError::QueueFull => unreachable!("handled above"),
                };
            }
        }
    }
    reject(trace);
    Err(last)
}

/// The QoS dispatcher: pops jobs in deficit-round-robin order, waits for
/// a shared admission slot, and routes each onto a shard. One thread, so
/// fairness decisions are serialized; the shard pipelines behind it stay
/// fully parallel. Jobs whose response handle was dropped or whose
/// deadline passed while queued are discarded without costing a permit.
fn dispatcher_loop(
    shared: Arc<Shared>,
    qos: Arc<QosShared>,
    admitted: Arc<AtomicUsize>,
    queue_depth: Option<usize>,
) {
    loop {
        // Take the next job in fair order (or drain and exit on stop).
        let job = {
            let mut q = qos.queue.lock().unwrap_or_else(PoisonError::into_inner);
            loop {
                if qos.stopped.load(Ordering::SeqCst) {
                    for (_, j) in q.drain() {
                        let _ = j.respond.send(Err(ClusterError::Stopped));
                    }
                    return;
                }
                match q.pop() {
                    Some((_tenant, job)) => break job,
                    None => {
                        q = qos
                            .cv
                            .wait_timeout(q, qos.opts.poll)
                            .unwrap_or_else(PoisonError::into_inner)
                            .0;
                    }
                }
            }
        };
        if job.cancel.load(Ordering::SeqCst) {
            continue;
        }
        if job.deadline.is_some_and(|d| d <= Instant::now()) {
            // The waiter timed itself out (and reported RequestTimeout);
            // routing the stale job would only burn shard capacity.
            continue;
        }
        // Wait for a shared admission slot. The bound still holds — the
        // fair queue sits *in front of* the permit pool, it does not
        // bypass it.
        let permit = loop {
            match AdmissionPermit::acquire(&admitted, queue_depth) {
                Ok(p) => break Some(p),
                Err(_) => {
                    if qos.stopped.load(Ordering::SeqCst)
                        || job.cancel.load(Ordering::SeqCst)
                    {
                        break None;
                    }
                    std::thread::sleep(qos.opts.poll);
                }
            }
        };
        let Some(permit) = permit else {
            if qos.stopped.load(Ordering::SeqCst) {
                let _ = job.respond.send(Err(ClusterError::Stopped));
            }
            continue;
        };
        // Queue time counts against the deadline: the shard sees only
        // what remains.
        let deadline = job.deadline.map(|d| d.saturating_duration_since(Instant::now()));
        match route_submit(&shared, job.session, job.inputs, deadline) {
            Ok((ticket, shard)) => {
                // If the receiver is gone the Dispatched (and its permit)
                // drops right here — the slot is never leaked.
                let _ = job.respond.send(Ok(Dispatched { ticket, shard, permit }));
            }
            Err(e) => {
                drop(permit);
                let _ = job.respond.send(Err(e));
            }
        }
    }
}

/// The supervisor: waits on the failure channel, maintains router health,
/// restarts downed shards (same store — warm keys), and re-dispatches
/// failed requests to healthy shards within the retry budget. Every event
/// it consumes terminates the request one way or another.
fn supervisor_loop(
    shared: Arc<Shared>,
    rx: Receiver<FailedRequest>,
    plan: Arc<CompiledPlan>,
    coord_opts: CoordinatorOptions,
    failure_tx: Sender<FailedRequest>,
    sup: SupervisorOptions,
    stop: Arc<AtomicBool>,
) {
    loop {
        match rx.recv_timeout(sup.poll) {
            Ok(ev) => {
                handle_failure(&shared, ev, &plan, &coord_opts, &failure_tx, &sup, &stop)
            }
            Err(RecvTimeoutError::Timeout) => {
                if stop.load(Ordering::SeqCst) {
                    // Fail any stragglers typed; new events can no longer
                    // arrive (all shards are drained before `stop` sets).
                    while let Ok(ev) = rx.try_recv() {
                        let _ = ev
                            .respond
                            .send(Err(RequestError::ExecFailed { reason: ev.reason }));
                    }
                    break;
                }
                check_stalls(&shared, &sup);
            }
            Err(RecvTimeoutError::Disconnected) => break,
        }
    }
}

fn handle_failure(
    shared: &Shared,
    ev: FailedRequest,
    plan: &Arc<CompiledPlan>,
    coord_opts: &CoordinatorOptions,
    failure_tx: &Sender<FailedRequest>,
    sup: &SupervisorOptions,
    stop: &AtomicBool,
) {
    let generation = shared.generation.load(Ordering::SeqCst);
    if ev.generation != generation {
        // From a topology that no longer exists: its shard ids are
        // meaningless now. Terminate typed rather than guess a mapping.
        let _ = ev.respond.send(Err(RequestError::ExecFailed { reason: ev.reason }));
        return;
    }
    let health = read(&shared.router).record_failure(ev.shard);
    if health == HealthState::Down && !stop.load(Ordering::SeqCst) {
        restart_shard(shared, ev.shard, plan, coord_opts, failure_tx, generation);
    }
    if ev.retries >= sup.max_retries || stop.load(Ordering::SeqCst) {
        let _ = ev.respond.send(Err(RequestError::ExecFailed { reason: ev.reason }));
        return;
    }
    // Redirect: walk forward from the failed shard to the next live one
    // (prefer a different shard; a 1-shard cluster retries in place on
    // the restarted coordinator).
    let shards = read(&shared.shards);
    let n = shards.len();
    let target = {
        let router = read(&shared.router);
        (1..n)
            .map(|k| (ev.shard + k) % n)
            .find(|&s| router.health(s) != HealthState::Down)
            // Single shard (or all others down): retry in place — the
            // clamp guards a raced shrink that left `ev.shard` dangling.
            .unwrap_or(ev.shard.min(n - 1))
    };
    shared.retries.fetch_add(1, Ordering::SeqCst);
    obs::trace::instant("retry", ev.trace);
    if let Err(respond) =
        shards[target].resubmit(ev.session, ev.inputs, ev.respond, ev.retries + 1, ev.trace)
    {
        // Target could not take it (stopped, or its store failed to
        // resolve): terminal typed failure.
        let _ = respond.send(Err(RequestError::ResolveFailed {
            reason: format!("retry {} after: {}", ev.retries + 1, ev.reason),
        }));
    }
}

/// Quarantine-and-restart: swap in a fresh coordinator over the SAME
/// shard-local store (cached keys stay warm — no regeneration), then
/// drain the failed one. Its metrics are retired so lifetime totals stay
/// exact.
fn restart_shard(
    shared: &Shared,
    shard: usize,
    plan: &Arc<CompiledPlan>,
    coord_opts: &CoordinatorOptions,
    failure_tx: &Sender<FailedRequest>,
    generation: u64,
) {
    let mut shards = write(&shared.shards);
    if shard >= shards.len() {
        return; // topology changed under us; the generation gate handles its events
    }
    let store = read(&shared.stores)[shard].clone();
    let replacement = Coordinator::start_supervised(
        plan.clone(),
        store,
        coord_opts.clone(),
        Some(FailureSink { shard, generation, tx: failure_tx.clone() }),
    );
    let mut old = std::mem::replace(&mut shards[shard], replacement);
    // Drain the failed coordinator: requests still queued behind the
    // panic either complete (their batches are independent) or re-enter
    // the failure channel and get retried/terminated.
    old.shutdown();
    shared
        .retired
        .lock()
        .unwrap_or_else(PoisonError::into_inner)
        .push(old.metrics.snapshot());
    shared.restarts.fetch_add(1, Ordering::SeqCst);
    obs::trace::instant("shard_restart", 0);
    read(&shared.router).mark_healthy(shard);
}

/// Queue-age sweep: an idle shard is healthy; a shard with in-flight
/// requests but no batch progress for `stall_after` degrades, and for
/// twice that is routed around entirely. Recomputed every tick — the
/// signal is a level, not a latch, so recovery clears it automatically.
fn check_stalls(shared: &Shared, sup: &SupervisorOptions) {
    let shards = read(&shared.shards);
    let router = read(&shared.router);
    for (i, c) in shards.iter().enumerate() {
        let state = if c.inflight.load(Ordering::SeqCst) == 0 {
            HealthState::Healthy
        } else {
            let idle = c.metrics.time_since_progress();
            if idle >= sup.stall_after * 2 {
                HealthState::Down
            } else if idle >= sup.stall_after {
                HealthState::Degraded
            } else {
                HealthState::Healthy
            }
        };
        router.set_stall(i, state);
    }
}
