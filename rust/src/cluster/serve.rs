//! The cluster proper: N coordinator shards behind one router, one shared
//! bounded admission queue, and merged observability.
//!
//! The program is compiled ONCE ([`compiler::compile`]) and the resulting
//! [`CompiledPlan`] is shared by every shard's workers
//! ([`Coordinator::start_with_plan`]), so all shards execute — and
//! `arch::sim` costs — the identical artifact. Keys are either replicated
//! (one `Arc<ServerKeys>` cloned per shard, [`Cluster::start`]) or
//! per-shard ([`Cluster::start_with_shard_keys`], e.g. one key set per
//! accelerator's HBM).
//!
//! Admission is permit-based: [`Cluster::submit`] atomically claims one of
//! `queue_depth` slots and hands the permit to the returned
//! [`ClusterResponse`]; the slot is released when the client drops the
//! handle (normally right after `recv`). At depth, `submit` fails fast
//! with [`ClusterError::ClusterFull`] instead of queueing unboundedly —
//! callers shed load or retry after draining, exactly the backpressure a
//! front door needs at millions-of-users scale.

use std::fmt;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{Receiver, RecvError};
use std::sync::Arc;

use super::router::{PlacementPolicy, Router};
use crate::compiler::{self, CompiledPlan};
use crate::coordinator::{Coordinator, CoordinatorOptions, MetricsSnapshot, SubmitError};
use crate::ir::Program;
use crate::tfhe::{LweCiphertext, ServerKeys};

#[derive(Debug, Clone)]
pub struct ClusterOptions {
    /// Number of coordinator shards (each with its own worker pool).
    pub shards: usize,
    /// How the router places requests onto shards.
    pub policy: PlacementPolicy,
    /// Cluster-wide admission bound: maximum outstanding responses before
    /// [`Cluster::submit`] returns [`ClusterError::ClusterFull`]. `None`
    /// admits without limit.
    pub queue_depth: Option<usize>,
    /// Per-shard coordinator configuration (workers, batcher, backend,
    /// optional per-shard `max_queue_depth`).
    pub coordinator: CoordinatorOptions,
}

impl Default for ClusterOptions {
    fn default() -> Self {
        Self {
            shards: 2,
            policy: PlacementPolicy::RoundRobin,
            queue_depth: None,
            coordinator: CoordinatorOptions::default(),
        }
    }
}

/// Error returned by [`Cluster::submit`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ClusterError {
    /// The shared admission queue is at `queue_depth` — shed load.
    ClusterFull,
    /// The routed shard's own `max_queue_depth` bound fired.
    ShardFull,
    /// The cluster (or the routed shard) has shut down.
    Stopped,
}

impl fmt::Display for ClusterError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ClusterError::ClusterFull => f.write_str("cluster admission queue full"),
            ClusterError::ShardFull => f.write_str("routed shard queue full"),
            ClusterError::Stopped => f.write_str("cluster stopped"),
        }
    }
}

impl std::error::Error for ClusterError {}

/// One slot in the shared admission queue; releases on drop.
#[derive(Debug)]
struct AdmissionPermit {
    admitted: Arc<AtomicUsize>,
}

impl AdmissionPermit {
    fn acquire(
        admitted: &Arc<AtomicUsize>,
        depth: Option<usize>,
    ) -> Result<Self, ClusterError> {
        if !crate::coordinator::server::try_claim_slot(admitted, depth) {
            return Err(ClusterError::ClusterFull);
        }
        Ok(Self { admitted: admitted.clone() })
    }
}

impl Drop for AdmissionPermit {
    fn drop(&mut self) {
        self.admitted.fetch_sub(1, Ordering::SeqCst);
    }
}

/// A pending response plus its admission slot. The slot frees when this
/// handle is dropped, so a client that holds N handles occupies N of the
/// cluster's `queue_depth` — backpressure is deterministic, independent of
/// worker timing.
#[derive(Debug)]
pub struct ClusterResponse {
    rx: Receiver<Vec<LweCiphertext>>,
    /// Which shard served this request (useful for affinity checks).
    pub shard: usize,
    _permit: AdmissionPermit,
}

impl ClusterResponse {
    /// Wait for the decryptable output ciphertexts.
    pub fn recv(&self) -> Result<Vec<LweCiphertext>, RecvError> {
        self.rx.recv()
    }
}

/// N replicated serving engines behind one admission-controlled router.
pub struct Cluster {
    shards: Vec<Coordinator>,
    router: Router,
    admitted: Arc<AtomicUsize>,
    queue_depth: Option<usize>,
    plan: Arc<CompiledPlan>,
    accepting: bool,
}

impl Cluster {
    /// Start with replicated keys: every shard serves under the same
    /// `ServerKeys` (one `Arc` clone each — no key material is copied).
    pub fn start(program: Program, keys: Arc<ServerKeys>, opts: ClusterOptions) -> Self {
        assert!(opts.shards >= 1, "cluster needs at least one shard");
        let shard_keys = vec![keys; opts.shards];
        Self::start_with_shard_keys(program, shard_keys, opts)
    }

    /// Start with per-shard keys (all generated for the same parameter
    /// set); `shard_keys.len()` overrides `opts.shards`.
    pub fn start_with_shard_keys(
        program: Program,
        shard_keys: Vec<Arc<ServerKeys>>,
        opts: ClusterOptions,
    ) -> Self {
        assert!(!shard_keys.is_empty(), "cluster needs at least one shard");
        assert_ne!(
            opts.queue_depth,
            Some(0),
            "queue_depth 0 would reject every request; use None for unbounded"
        );
        let params = &shard_keys[0].params;
        assert!(
            shard_keys.iter().all(|k| k.params.name == params.name),
            "all shards must use one parameter set"
        );
        // Compile once; every shard executes (and `arch::sim` costs) the
        // same artifact.
        let plan = Arc::new(compiler::compile(&program, params, opts.coordinator.plan_capacity));
        let shards: Vec<Coordinator> = shard_keys
            .into_iter()
            .map(|keys| Coordinator::start_with_plan(plan.clone(), keys, opts.coordinator.clone()))
            .collect();
        let router = Router::new(opts.policy, shards.len());
        Self {
            shards,
            router,
            admitted: Arc::new(AtomicUsize::new(0)),
            queue_depth: opts.queue_depth,
            plan,
            accepting: true,
        }
    }

    /// The compiled plan every shard executes.
    pub fn plan(&self) -> &CompiledPlan {
        &self.plan
    }

    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    pub fn policy(&self) -> PlacementPolicy {
        self.router.policy()
    }

    /// Currently admitted (undropped) responses across the cluster.
    pub fn outstanding(&self) -> usize {
        self.admitted.load(Ordering::SeqCst)
    }

    /// Admit, route, and submit one encrypted query for `client_id`. The
    /// inputs are consumed either way; a single-submitter client that
    /// wants lossless backpressure should drain a pending response while
    /// [`Self::outstanding`] sits at the queue depth (as the drivers do)
    /// rather than bounce off [`ClusterError::ClusterFull`].
    pub fn submit(
        &self,
        client_id: u64,
        inputs: Vec<LweCiphertext>,
    ) -> Result<ClusterResponse, ClusterError> {
        if !self.accepting {
            return Err(ClusterError::Stopped);
        }
        // The permit is dropped (slot released) on any error path below.
        let permit = AdmissionPermit::acquire(&self.admitted, self.queue_depth)?;
        // Outstanding counts are gathered lazily — only the
        // least-outstanding policy reads them.
        let shard = self.router.place(client_id, || {
            self.shards.iter().map(|c| c.inflight.load(Ordering::SeqCst)).collect()
        });
        let rx = self.shards[shard].submit(inputs).map_err(|e| match e {
            SubmitError::Stopped => ClusterError::Stopped,
            SubmitError::QueueFull => ClusterError::ShardFull,
        })?;
        Ok(ClusterResponse { rx, shard, _permit: permit })
    }

    /// Per-shard metrics, indexed by shard id.
    pub fn shard_snapshots(&self) -> Vec<MetricsSnapshot> {
        self.shards.iter().map(|c| c.metrics.snapshot()).collect()
    }

    /// Aggregate cluster metrics: counters summed, percentiles recomputed
    /// over the concatenated per-shard samples
    /// ([`MetricsSnapshot::merge`]).
    pub fn snapshot(&self) -> MetricsSnapshot {
        MetricsSnapshot::merge(&self.shard_snapshots())
    }

    /// Graceful drain: stop admitting, flush every shard's batcher (all
    /// already-admitted requests are answered), and join dispatch + worker
    /// threads. Subsequent [`Self::submit`] calls return
    /// [`ClusterError::Stopped`].
    pub fn shutdown(&mut self) {
        self.accepting = false;
        for shard in &mut self.shards {
            shard.shutdown();
        }
    }
}
