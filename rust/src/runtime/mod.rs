//! PJRT runtime: load AOT-compiled HLO-text artifacts (produced once by
//! `python/compile/aot.py`) and execute them on the request path.
//!
//! Interchange format is **HLO text** — jax ≥ 0.5 serialized protos carry
//! 64-bit instruction ids which xla_extension 0.5.1 rejects; the text parser
//! reassigns ids (see /opt/xla-example/README.md).

//! The PJRT execution path needs the `xla` crate, which the offline build
//! image cannot resolve; it is gated behind the `xla` cargo feature so the
//! default build stays dependency-free. The manifest loader is always
//! available (it is pure Rust and also used by tooling).

mod artifact;
#[cfg(feature = "xla")]
mod exec;
pub mod faults;
#[cfg(feature = "xla")]
mod pbs_backend;

pub use artifact::{Artifact, ArtifactManifest};
pub use faults::{FaultCounts, FaultPlan, FaultSpec, FaultyBackend, FaultyStore};
#[cfg(feature = "xla")]
pub use exec::{XlaEngine, XlaExecutable};
#[cfg(feature = "xla")]
pub use pbs_backend::XlaPbsBackend;
