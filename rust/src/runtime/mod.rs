//! PJRT runtime: load AOT-compiled HLO-text artifacts (produced once by
//! `python/compile/aot.py`) and execute them on the request path.
//!
//! Interchange format is **HLO text** — jax ≥ 0.5 serialized protos carry
//! 64-bit instruction ids which xla_extension 0.5.1 rejects; the text parser
//! reassigns ids (see /opt/xla-example/README.md).

mod artifact;
mod exec;
mod pbs_backend;

pub use artifact::{Artifact, ArtifactManifest};
pub use exec::{XlaEngine, XlaExecutable};
pub use pbs_backend::XlaPbsBackend;
