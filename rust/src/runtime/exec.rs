//! PJRT CPU client wrapper: compile HLO text once, execute many times.
//!
//! Note: the `xla` crate's `PjRtClient` is `Rc`-based (not `Send`), so an
//! [`XlaEngine`] is owned by a single executor thread; the coordinator
//! communicates with it over channels (see `coordinator::server`).

use crate::util::err::{Context, Result};
use std::collections::HashMap;
use std::path::Path;
use std::rc::Rc;

use super::artifact::ArtifactManifest;

/// A compiled, ready-to-execute XLA computation.
pub struct XlaExecutable {
    exe: xla::PjRtLoadedExecutable,
}

impl XlaExecutable {
    /// Execute with the given input literals. The AOT path lowers with
    /// `return_tuple=True`, so the single output is a tuple; this returns
    /// the tuple elements.
    pub fn run(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let bufs = self.exe.execute::<xla::Literal>(inputs).context("xla execute")?;
        let mut lit = bufs[0][0].to_literal_sync().context("device->host")?;
        match lit.decompose_tuple() {
            Ok(elems) if !elems.is_empty() => Ok(elems),
            _ => Ok(vec![lit]),
        }
    }
}

/// Owns the PJRT client and a cache of compiled executables, keyed by
/// `(name, param_tag)`.
pub struct XlaEngine {
    client: xla::PjRtClient,
    manifest: ArtifactManifest,
    cache: HashMap<(String, String), Rc<XlaExecutable>>,
}

impl XlaEngine {
    /// Create a CPU PJRT client and load the artifact manifest from `dir`.
    pub fn new(dir: impl AsRef<Path>) -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let manifest = ArtifactManifest::load(dir)?;
        Ok(Self { client, manifest, cache: HashMap::new() })
    }

    pub fn manifest(&self) -> &ArtifactManifest {
        &self.manifest
    }

    /// Compile (or fetch from cache) the artifact `(name, param_tag)`.
    pub fn executable(&mut self, name: &str, param_tag: &str) -> Result<Rc<XlaExecutable>> {
        let key = (name.to_string(), param_tag.to_string());
        if let Some(e) = self.cache.get(&key) {
            return Ok(e.clone());
        }
        let art = self
            .manifest
            .find(name, param_tag)
            .with_context(|| format!("artifact {name}:{param_tag} not in manifest"))?;
        let proto = xla::HloModuleProto::from_text_file(
            art.path.to_str().context("artifact path not utf-8")?,
        )
        .with_context(|| format!("parsing HLO text {}", art.path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).context("PJRT compile")?;
        let rc = Rc::new(XlaExecutable { exe });
        self.cache.insert(key, rc.clone());
        Ok(rc)
    }

    /// Compile a raw HLO text file (used by tests and tools).
    pub fn compile_file(&self, path: impl AsRef<Path>) -> Result<XlaExecutable> {
        let proto = xla::HloModuleProto::from_text_file(
            path.as_ref().to_str().context("path not utf-8")?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).context("PJRT compile")?;
        Ok(XlaExecutable { exe })
    }
}
