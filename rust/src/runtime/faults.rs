//! Deterministic, seed-driven fault injection for the serving stack.
//!
//! A [`FaultPlan`] is derived once from a seed and a [`FaultSpec`]: it
//! schedules worker panics and latency spikes at specific blind-rotate
//! operation indices, and key-resolve failures at specific resolve-call
//! indices. [`FaultyBackend`] wraps any [`PbsBackend`] and consults the
//! plan before every blind rotation; [`FaultyStore`] wraps any
//! [`KeyStore`] and consults it on every fallible resolve. The indices to
//! fault are a pure function of `(seed, spec)`, so a chaos run is
//! reproducible: the same seed injects the same faults at the same points
//! in the global operation order, and CI can sweep seeds.
//!
//! The plan's counters are shared (`Arc`) across every wrapper cloned
//! from it, so the schedule is global across workers and shards — one
//! fault stream per cluster, not one per thread. Injection is strictly
//! opt-in: the plain `BackendKind::Native` serving path never constructs
//! these wrappers and pays nothing.

use std::collections::BTreeSet;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use crate::compiler::PbsBackend;
use crate::params::ParamSet;
use crate::tenant::{KeyHandle, KeyStore, KeyStoreStats, SessionId};
use crate::tfhe::{GlweCiphertext, LweCiphertext, ServerKeys};
use crate::util::rng::Rng;

/// How many faults to schedule, and inside which index windows.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FaultSpec {
    /// Blind-rotate calls with index `< op_horizon` are eligible for
    /// injected panics and delays; later calls run clean (the recovery
    /// phase chaos tests assert on).
    pub op_horizon: u64,
    /// Number of distinct blind-rotate indices that panic.
    pub panics: usize,
    /// Number of distinct blind-rotate indices that sleep `delay` first
    /// (the slow-shard signal for deadline and stall handling).
    pub delays: usize,
    /// Injected latency per scheduled delay.
    pub delay: Duration,
    /// Resolve calls with index `< resolve_horizon` are eligible for
    /// injected resolve failures.
    pub resolve_horizon: u64,
    /// Number of distinct resolve indices that fail.
    pub resolve_failures: usize,
}

impl FaultSpec {
    /// A quiet spec: nothing is ever injected (useful as a baseline).
    pub fn none() -> Self {
        Self {
            op_horizon: 0,
            panics: 0,
            delays: 0,
            delay: Duration::ZERO,
            resolve_horizon: 0,
            resolve_failures: 0,
        }
    }
}

/// Counters of faults actually injected so far (for reports and the
/// `serve --chaos` summary).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FaultCounts {
    pub panics: u64,
    pub delays: u64,
    pub resolve_failures: u64,
}

/// Draw `count` distinct indices in `[0, horizon)` from `rng`. With
/// `count >= horizon` every index faults — a legal (total-failure) plan.
fn schedule(rng: &mut Rng, count: usize, horizon: u64) -> BTreeSet<u64> {
    let mut out = BTreeSet::new();
    if horizon == 0 {
        return out;
    }
    let want = count.min(horizon as usize);
    while out.len() < want {
        out.insert(rng.below(horizon));
    }
    out
}

/// The derived fault schedule plus the live operation counters. Shared
/// via `Arc` by every [`FaultyBackend`]/[`FaultyStore`] wrapper of one
/// chaos run.
#[derive(Debug)]
pub struct FaultPlan {
    seed: u64,
    panics: BTreeSet<u64>,
    delays: BTreeSet<u64>,
    delay: Duration,
    resolve_failures: BTreeSet<u64>,
    ops: AtomicU64,
    resolves: AtomicU64,
    armed: AtomicBool,
    injected_panics: AtomicU64,
    injected_delays: AtomicU64,
    injected_resolve_failures: AtomicU64,
}

impl FaultPlan {
    /// Derive the schedule. Deterministic: the faulted indices are a pure
    /// function of `(seed, spec)`.
    pub fn from_seed(seed: u64, spec: &FaultSpec) -> Self {
        // Domain-separated sub-streams so changing one knob (e.g. the
        // panic count) never reshuffles the other schedules.
        let mut panic_rng = Rng::new(seed ^ 0x70A6_1C5);
        let mut delay_rng = Rng::new(seed ^ 0xDE1A_75);
        let mut resolve_rng = Rng::new(seed ^ 0x9E50_1FE);
        Self {
            seed,
            panics: schedule(&mut panic_rng, spec.panics, spec.op_horizon),
            delays: schedule(&mut delay_rng, spec.delays, spec.op_horizon),
            delay: spec.delay,
            resolve_failures: schedule(
                &mut resolve_rng,
                spec.resolve_failures,
                spec.resolve_horizon,
            ),
            ops: AtomicU64::new(0),
            resolves: AtomicU64::new(0),
            armed: AtomicBool::new(true),
            injected_panics: AtomicU64::new(0),
            injected_delays: AtomicU64::new(0),
            injected_resolve_failures: AtomicU64::new(0),
        }
    }

    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// The scheduled blind-rotate indices that panic (inspection/tests).
    pub fn panic_schedule(&self) -> Vec<u64> {
        self.panics.iter().copied().collect()
    }

    /// Stop injecting from now on (counters keep advancing). Chaos tests
    /// disarm before their recovery phase so post-recovery serving is
    /// provably fault-free regardless of where the counters stand.
    pub fn disarm(&self) {
        self.armed.store(false, Ordering::SeqCst);
    }

    /// Faults injected so far.
    pub fn injected(&self) -> FaultCounts {
        FaultCounts {
            panics: self.injected_panics.load(Ordering::SeqCst),
            delays: self.injected_delays.load(Ordering::SeqCst),
            resolve_failures: self.injected_resolve_failures.load(Ordering::SeqCst),
        }
    }

    /// Called by [`FaultyBackend`] before each blind rotation: may sleep,
    /// may panic (the panic is the injected fault — the coordinator's
    /// `catch_unwind` boundary turns it into typed request failures).
    fn on_blind_rotate(&self) {
        let n = self.ops.fetch_add(1, Ordering::SeqCst);
        if !self.armed.load(Ordering::SeqCst) {
            return;
        }
        if self.delays.contains(&n) {
            self.injected_delays.fetch_add(1, Ordering::SeqCst);
            crate::obs::trace::instant("fault_delay", 0);
            std::thread::sleep(self.delay);
        }
        if self.panics.contains(&n) {
            self.injected_panics.fetch_add(1, Ordering::SeqCst);
            crate::obs::trace::instant("fault_panic", 0);
            panic!("injected backend fault at blind-rotate op {n} (seed {})", self.seed);
        }
    }

    /// Called by [`FaultyStore`] on each fallible resolve; `Some(reason)`
    /// means this call must fail.
    fn on_resolve(&self) -> Option<String> {
        let n = self.resolves.fetch_add(1, Ordering::SeqCst);
        if !self.armed.load(Ordering::SeqCst) {
            return None;
        }
        if self.resolve_failures.contains(&n) {
            self.injected_resolve_failures.fetch_add(1, Ordering::SeqCst);
            crate::obs::trace::instant("fault_resolve", 0);
            return Some(format!("injected resolve failure at call {n} (seed {})", self.seed));
        }
        None
    }
}

/// A [`PbsBackend`] that consults a [`FaultPlan`] before every blind
/// rotation and otherwise delegates. Wraps the native backend on the
/// `BackendKind::NativeChaos` serving path.
pub struct FaultyBackend<B> {
    inner: B,
    plan: Arc<FaultPlan>,
}

impl<B: PbsBackend> FaultyBackend<B> {
    pub fn new(inner: B, plan: Arc<FaultPlan>) -> Self {
        Self { inner, plan }
    }

    /// The wrapped backend (the coordinator rebinds tenant keys through
    /// this).
    pub fn inner_mut(&mut self) -> &mut B {
        &mut self.inner
    }
}

impl<B: PbsBackend> PbsBackend for FaultyBackend<B> {
    fn keyswitch(&mut self, ct_long: &LweCiphertext) -> LweCiphertext {
        self.inner.keyswitch(ct_long)
    }

    fn blind_rotate_batch(
        &mut self,
        cts_short: &[LweCiphertext],
        lut_poly: &[u64],
    ) -> Vec<GlweCiphertext> {
        self.plan.on_blind_rotate();
        self.inner.blind_rotate_batch(cts_short, lut_poly)
    }

    fn sample_extract(&mut self, acc: &GlweCiphertext) -> LweCiphertext {
        self.inner.sample_extract(acc)
    }

    fn params(&self) -> &ParamSet {
        self.inner.params()
    }

    fn take_bsk_bytes_streamed(&mut self) -> u64 {
        self.inner.take_bsk_bytes_streamed()
    }

    fn take_fft_hist(&mut self) -> crate::obs::hist::Log2Histogram {
        self.inner.take_fft_hist()
    }
}

/// A [`KeyStore`] that injects resolve failures per the plan and
/// delegates everything else. Only `try_resolve` faults — `resolve`
/// stays infallible so control paths that cannot shed (reshard
/// migration, pre-warming) are unaffected.
pub struct FaultyStore {
    inner: Arc<dyn KeyStore>,
    plan: Arc<FaultPlan>,
}

impl FaultyStore {
    pub fn new(inner: Arc<dyn KeyStore>, plan: Arc<FaultPlan>) -> Self {
        Self { inner, plan }
    }
}

impl KeyStore for FaultyStore {
    fn params(&self) -> &ParamSet {
        self.inner.params()
    }

    fn is_single_key(&self) -> bool {
        self.inner.is_single_key()
    }

    fn resolve(&self, session: SessionId) -> KeyHandle {
        self.inner.resolve(session)
    }

    fn try_resolve(&self, session: SessionId) -> Result<KeyHandle, String> {
        match self.plan.on_resolve() {
            Some(reason) => Err(reason),
            None => self.inner.try_resolve(session),
        }
    }

    fn register(&self, session: SessionId, keys: Arc<ServerKeys>) -> KeyHandle {
        self.inner.register(session, keys)
    }

    fn supports_register(&self) -> bool {
        self.inner.supports_register()
    }

    fn register_uploaded(
        &self,
        session: SessionId,
        keys: Arc<ServerKeys>,
    ) -> Result<KeyHandle, crate::tenant::RegisterError> {
        self.inner.register_uploaded(session, keys)
    }

    fn evict(&self, session: SessionId) -> Option<Arc<ServerKeys>> {
        self.inner.evict(session)
    }

    fn resident(&self) -> Vec<SessionId> {
        self.inner.resident()
    }

    fn stats(&self) -> KeyStoreStats {
        self.inner.stats()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::params::TEST1;
    use crate::tenant::StaticKeys;
    use crate::tfhe::SecretKeys;

    fn spec() -> FaultSpec {
        FaultSpec {
            op_horizon: 32,
            panics: 4,
            delays: 2,
            delay: Duration::from_millis(1),
            resolve_horizon: 16,
            resolve_failures: 3,
        }
    }

    #[test]
    fn schedule_is_deterministic_per_seed_and_distinct_across_seeds() {
        let a = FaultPlan::from_seed(7, &spec());
        let b = FaultPlan::from_seed(7, &spec());
        let c = FaultPlan::from_seed(8, &spec());
        assert_eq!(a.panics, b.panics);
        assert_eq!(a.delays, b.delays);
        assert_eq!(a.resolve_failures, b.resolve_failures);
        assert_ne!(a.panics, c.panics, "different seeds should draw different schedules");
        assert_eq!(a.panics.len(), 4);
        assert!(a.panics.iter().all(|&i| i < 32));
        assert_eq!(a.resolve_failures.len(), 3);
        assert!(a.resolve_failures.iter().all(|&i| i < 16));
    }

    #[test]
    fn total_failure_plan_is_legal_and_bounded_by_horizon() {
        let p = FaultPlan::from_seed(
            1,
            &FaultSpec { op_horizon: 3, panics: 100, ..FaultSpec::none() },
        );
        assert_eq!(p.panic_schedule(), vec![0, 1, 2]);
        let quiet = FaultPlan::from_seed(1, &FaultSpec::none());
        assert!(quiet.panics.is_empty() && quiet.resolve_failures.is_empty());
    }

    #[test]
    fn resolve_failures_fire_at_scheduled_indices_then_disarm_silences() {
        let mut s = spec();
        s.resolve_failures = 2;
        let plan = Arc::new(FaultPlan::from_seed(3, &s));
        let mut rng = Rng::new(5);
        let sk = SecretKeys::generate(&TEST1, &mut rng);
        let keys = Arc::new(ServerKeys::generate(&sk, &mut rng));
        let store =
            FaultyStore::new(Arc::new(StaticKeys::new(keys)) as Arc<dyn KeyStore>, plan.clone());
        let mut failed = Vec::new();
        for i in 0..16u64 {
            if store.try_resolve(SessionId(0)).is_err() {
                failed.push(i);
            }
        }
        let expected: Vec<u64> = plan.resolve_failures.iter().copied().collect();
        assert_eq!(failed, expected, "failures at exactly the scheduled call indices");
        assert_eq!(plan.injected().resolve_failures, 2);
        // Past the horizon — and after disarm — everything succeeds.
        plan.disarm();
        for _ in 0..8 {
            assert!(store.try_resolve(SessionId(1)).is_ok());
        }
        assert_eq!(plan.injected().resolve_failures, 2);
    }

    #[test]
    fn faulty_backend_panics_at_scheduled_rotate_and_matches_inner_otherwise() {
        let mut rng = Rng::new(9);
        let sk = SecretKeys::generate(&TEST1, &mut rng);
        let keys = Arc::new(ServerKeys::generate(&sk, &mut rng));
        let plan = Arc::new(FaultPlan::from_seed(
            2,
            &FaultSpec { op_horizon: 1, panics: 1, ..FaultSpec::none() },
        ));
        let mut be = FaultyBackend::new(
            crate::compiler::NativePbsBackend::shared(keys.clone()),
            plan.clone(),
        );
        let lut = crate::tfhe::make_lut_poly(&TEST1, |m| (m + 1) % 16);
        let ct = crate::tfhe::pbs::encrypt_message(3, &sk, &mut rng);
        // Op 0 is scheduled to panic.
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| be.pbs(&ct, &lut)));
        assert!(r.is_err(), "scheduled op must panic");
        assert_eq!(plan.injected().panics, 1);
        // Op 1 is clean and bitwise equals the unwrapped backend.
        let out = be.pbs(&ct, &lut);
        let mut plain = crate::compiler::NativePbsBackend::shared(keys);
        let expect = plain.pbs(&ct, &lut);
        assert_eq!(out, expect, "clean ops must be bitwise-identical to the inner backend");
    }
}
