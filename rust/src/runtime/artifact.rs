//! Artifact manifest: maps logical computation names (e.g. `blind_rotate`,
//! `keyswitch`) + parameter-set tags to HLO text files under `artifacts/`.
//!
//! The manifest is written by `python/compile/aot.py` as a small JSON file;
//! we parse it with the dependency-free reader in [`crate::util::json`].

use crate::anyhow;
use crate::util::err::{Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

use crate::util::json::JsonValue;

/// One AOT-compiled computation.
#[derive(Debug, Clone)]
pub struct Artifact {
    /// Logical name, e.g. `"blind_rotate"`.
    pub name: String,
    /// Parameter-set tag, e.g. `"test1"`.
    pub param_tag: String,
    /// Path to the HLO text file.
    pub path: PathBuf,
    /// Input descriptions `(name, dtype, shape)` as recorded by aot.py.
    pub inputs: Vec<(String, String, Vec<usize>)>,
}

/// Parsed `artifacts/manifest.json`.
#[derive(Debug, Clone, Default)]
pub struct ArtifactManifest {
    pub artifacts: Vec<Artifact>,
    by_key: HashMap<(String, String), usize>,
}

impl ArtifactManifest {
    /// Load `manifest.json` from an artifacts directory.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref();
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .with_context(|| format!("reading {}/manifest.json (run `make artifacts`)", dir.display()))?;
        let v = JsonValue::parse(&text).context("parsing manifest.json")?;
        let mut out = ArtifactManifest::default();
        let arr = v
            .get("artifacts")
            .and_then(JsonValue::as_array)
            .ok_or_else(|| anyhow!("manifest.json: missing `artifacts` array"))?;
        for a in arr {
            let name = a
                .get("name")
                .and_then(JsonValue::as_str)
                .ok_or_else(|| anyhow!("artifact missing name"))?
                .to_string();
            let param_tag = a
                .get("param_tag")
                .and_then(JsonValue::as_str)
                .unwrap_or("default")
                .to_string();
            let file = a
                .get("file")
                .and_then(JsonValue::as_str)
                .ok_or_else(|| anyhow!("artifact missing file"))?;
            let mut inputs = Vec::new();
            if let Some(ins) = a.get("inputs").and_then(JsonValue::as_array) {
                for i in ins {
                    let iname = i.get("name").and_then(JsonValue::as_str).unwrap_or("").to_string();
                    let dtype = i.get("dtype").and_then(JsonValue::as_str).unwrap_or("").to_string();
                    let shape = i
                        .get("shape")
                        .and_then(JsonValue::as_array)
                        .map(|s| s.iter().filter_map(|d| d.as_f64().map(|f| f as usize)).collect())
                        .unwrap_or_default();
                    inputs.push((iname, dtype, shape));
                }
            }
            let idx = out.artifacts.len();
            out.by_key.insert((name.clone(), param_tag.clone()), idx);
            out.artifacts.push(Artifact { name, param_tag, path: dir.join(file), inputs });
        }
        Ok(out)
    }

    /// Find an artifact by logical name + parameter tag.
    pub fn find(&self, name: &str, param_tag: &str) -> Option<&Artifact> {
        self.by_key
            .get(&(name.to_string(), param_tag.to_string()))
            .map(|&i| &self.artifacts[i])
    }
}
