//! Serving metrics: latency distribution + throughput counters, with
//! per-tenant attribution and key-cache observability.

use std::collections::BTreeMap;
use std::sync::{Mutex, MutexGuard, PoisonError};
use std::time::{Duration, Instant};

use crate::obs::drift::{merge_profiles, PlanBatchProfile};
use crate::obs::hist::StageHists;
use crate::tenant::SessionId;
use crate::util::stats;
use crate::util::stats::Reservoir;

/// Retained samples per distribution. Below this the reservoirs hold the
/// raw streams exactly (so merges and percentiles over short runs are
/// unchanged from the unbounded vectors they replaced); above it memory
/// stays constant no matter how many requests a soak serves.
const SAMPLE_CAP: usize = 4096;

/// Retained latency samples *per tenant*. Smaller than the global cap —
/// the per-tenant reservoirs exist for tail attribution (fairness tests,
/// the autoscaler's worst-tenant p99), not for high-resolution
/// distributions, and a million-tenant soak holds one reservoir per
/// *observed* tenant.
const SESSION_SAMPLE_CAP: usize = 512;

/// Seed domain for per-tenant latency reservoirs: mixed with the session
/// id so every tenant's retained subsample is a deterministic function of
/// its own record stream (and nothing else).
const SESSION_RESERVOIR_SEED: u64 = 0xD3_5EED;

#[derive(Debug)]
struct Inner {
    latencies_ms: Reservoir,
    queue_ms: Reservoir,
    batches: usize,
    batch_sizes: Reservoir,
    requests: usize,
    pbs_executed: usize,
    ks_executed: u64,
    bsk_bytes_streamed: u64,
    keyed_batch_splits: u64,
    session_requests: BTreeMap<u64, u64>,
    /// Per-tenant latency reservoirs, keyed by session id. Created lazily
    /// on a tenant's first served request.
    session_latencies: BTreeMap<u64, Reservoir>,
    exec_failures: u64,
    failed_requests: u64,
    worker_respawns: u64,
    request_timeouts: u64,
    /// Last time a worker made observable progress (finished or failed a
    /// batch). Drives the cluster supervisor's stall detector.
    last_progress: Option<Instant>,
    /// Per-stage timing histograms (queue filled here, execution stages
    /// pushed by workers via `record_stage_times`); empty unless
    /// `obs::enabled`.
    stage: StageHists,
    /// Per-schedule-batch measured profiles pushed by workers via
    /// `record_batch_profiles`; empty unless `obs::enabled`.
    plan_batch_profiles: Vec<PlanBatchProfile>,
}

impl Default for Inner {
    fn default() -> Self {
        // Fixed, distinct seeds: the retained samples are a deterministic
        // function of the record stream alone.
        Self {
            latencies_ms: Reservoir::new(SAMPLE_CAP, 0xA11),
            queue_ms: Reservoir::new(SAMPLE_CAP, 0xB22),
            batches: 0,
            batch_sizes: Reservoir::new(SAMPLE_CAP, 0xC33),
            requests: 0,
            pbs_executed: 0,
            ks_executed: 0,
            bsk_bytes_streamed: 0,
            keyed_batch_splits: 0,
            session_requests: BTreeMap::new(),
            session_latencies: BTreeMap::new(),
            exec_failures: 0,
            failed_requests: 0,
            worker_respawns: 0,
            request_timeouts: 0,
            last_progress: None,
            stage: StageHists::default(),
            plan_batch_profiles: Vec::new(),
        }
    }
}

/// Thread-safe metrics sink shared by batcher and workers.
#[derive(Debug)]
pub struct Metrics {
    inner: Mutex<Inner>,
    started: Option<Instant>,
}

/// A default `Metrics` is a live sink (clock started), identical to
/// [`Metrics::new`] — so `#[derive(Default)]` works on structs embedding
/// one and the throughput denominator is never zero-epoch garbage.
impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

#[derive(Debug, Clone, Default)]
pub struct MetricsSnapshot {
    pub requests: usize,
    pub batches: usize,
    pub pbs_executed: usize,
    pub mean_batch_size: f64,
    pub p50_latency_ms: f64,
    pub p99_latency_ms: f64,
    pub mean_queue_ms: f64,
    pub throughput_rps: f64,
    pub elapsed_s: f64,
    /// Key switches the workers actually executed — with the plan-driven
    /// path this equals `ks_dedup.after x requests`, the measured
    /// realization of the compiler's KS-dedup (cross-check against
    /// `arch::sim::SimResult::ks_count`).
    pub ks_executed: u64,
    /// Total Fourier-BSK bytes the workers' blind rotations streamed.
    pub bsk_bytes_streamed: u64,
    /// Amortized BSK bytes per executed PBS — the key-reuse metric: equals
    /// one full BSK stream per PBS when batches degenerate to size 1 and
    /// shrinks ~Bx when dynamic batches of B fuse their sweeps.
    pub bsk_bytes_per_pbs: f64,
    /// Extra execution sub-batches the keyed batcher produced beyond one
    /// per collected batch: a collected batch spanning k distinct tenant
    /// key sets contributes k-1 (the multi-tenant batching-efficiency
    /// tax; always 0 on the `StaticKeys` compat path).
    pub keyed_batch_splits: u64,
    /// Requests served per session id — the per-tenant view. Values sum
    /// to `requests`.
    pub session_requests: BTreeMap<u64, u64>,
    /// Per-tenant latency samples (ms), keyed by session id — the tail
    /// attribution the fairness tests and the autoscaler need (a cluster
    /// p99 cannot say *which* tenant is slow). Same reservoir policy as
    /// the global samples, at [`SESSION_SAMPLE_CAP`]; merge concatenates
    /// per key so merged per-tenant percentiles are computed over the
    /// union of shard samples.
    pub session_latency_ms: BTreeMap<u64, Vec<f64>>,
    /// QoS: submits rejected because the tenant's token bucket was empty
    /// (cluster-level, from `ClusterError::Throttled` rejections; zero
    /// in per-shard snapshots and whenever QoS is off).
    pub qos_throttled: u64,
    /// QoS: submits rejected because the tenant's fair-queue lane was at
    /// its depth bound (cluster-level; zero when QoS is off).
    pub qos_queue_rejections: u64,
    /// Autoscaler scale-up reshards performed (wrapper-level; zero
    /// without `--autoscale`).
    pub autoscale_ups: u64,
    /// Autoscaler scale-down reshards performed (wrapper-level).
    pub autoscale_downs: u64,
    /// Tenant key-store counters (filled from `KeyStore::stats` by
    /// `Coordinator::snapshot`; zero on a bare `Metrics::snapshot`).
    pub key_hits: u64,
    pub key_misses: u64,
    pub key_evictions: u64,
    pub key_regenerations: u64,
    /// Key sets resident in the store at snapshot time (a gauge: merge
    /// sums it across shard-local stores into cluster-wide residency).
    pub key_resident: usize,
    /// Resident key sets that are *pinned* (client-uploaded material the
    /// server cannot re-derive; capacity eviction skips them). Gauge,
    /// summed across shards like `key_resident`.
    pub key_pinned: usize,
    /// Batch executions that panicked inside the backend and were caught
    /// at the worker's `catch_unwind` boundary.
    pub exec_failures: u64,
    /// Requests that received a typed failure from this shard (each
    /// failed *attempt* counts; a request retried elsewhere and served
    /// there still counts one failure here).
    pub failed_requests: u64,
    /// In-place worker engine rebuilds after a caught panic.
    pub worker_respawns: u64,
    /// Tickets whose `wait()` expired before a response arrived.
    pub request_timeouts: u64,
    /// Failed requests re-dispatched to another shard by the cluster
    /// supervisor (cluster-level; zero in per-shard snapshots).
    pub request_retries: u64,
    /// Admission-time redirects around an unhealthy shard
    /// (cluster-level; zero in per-shard snapshots).
    pub request_redirects: u64,
    /// Shard quarantine-and-restart cycles (cluster-level; zero in
    /// per-shard snapshots).
    pub shard_restarts: u64,
    /// Per-shard blind-rotation worker threads (filled by
    /// `Coordinator::snapshot`; merge keeps the max across shards).
    pub fft_threads: usize,
    /// Whether this shard's parameter set selects the cache-blocked FFT
    /// schedule (filled by `Coordinator::snapshot`; merge ORs shards).
    pub blocked_fft: bool,
    /// Per-request latency samples (ms). Retained so shard snapshots can
    /// be merged into aggregate percentiles (percentiles do not compose
    /// from per-shard percentiles). Held in a seed-deterministic bounded
    /// reservoir: exact below [`SAMPLE_CAP`], a uniform subsample past it
    /// — so a soak's snapshot memory is constant in request count.
    pub latency_samples_ms: Vec<f64>,
    /// Per-request queueing-delay samples (ms), same reservoir policy.
    pub queue_samples_ms: Vec<f64>,
    /// Per-batch size samples, same reservoir policy.
    pub batch_size_samples: Vec<f64>,
    /// Per-stage timing histograms (queue/keyswitch/blind-rotate/
    /// sample-extract/FFT); empty unless observability was enabled.
    /// Histograms merge exactly, so cluster roll-ups lose nothing.
    pub stage: StageHists,
    /// Per-schedule-batch measured execution profiles for cost-model
    /// drift attribution (`obs::drift::attribute`); empty unless
    /// observability was enabled.
    pub plan_batch_profiles: Vec<PlanBatchProfile>,
}

impl MetricsSnapshot {
    /// Aggregate shard snapshots into one cluster view: counters sum
    /// (including the per-tenant request map and key-store counters), the
    /// latency/queue/batch distributions are recomputed over the
    /// concatenated raw samples (so merged p50/p99 are the true cluster
    /// percentiles, not an average of per-shard percentiles), and
    /// `bsk_bytes_per_pbs` is the PBS-weighted mean (total bytes / total
    /// PBS), not the mean of per-shard ratios.
    pub fn merge(shards: &[MetricsSnapshot]) -> MetricsSnapshot {
        let mut out = MetricsSnapshot::default();
        for s in shards {
            out.requests += s.requests;
            out.batches += s.batches;
            out.pbs_executed += s.pbs_executed;
            out.ks_executed += s.ks_executed;
            out.bsk_bytes_streamed += s.bsk_bytes_streamed;
            out.keyed_batch_splits += s.keyed_batch_splits;
            for (&session, &n) in &s.session_requests {
                *out.session_requests.entry(session).or_insert(0) += n;
            }
            for (&session, samples) in &s.session_latency_ms {
                out.session_latency_ms
                    .entry(session)
                    .or_default()
                    .extend_from_slice(samples);
            }
            out.qos_throttled += s.qos_throttled;
            out.qos_queue_rejections += s.qos_queue_rejections;
            out.autoscale_ups += s.autoscale_ups;
            out.autoscale_downs += s.autoscale_downs;
            out.exec_failures += s.exec_failures;
            out.failed_requests += s.failed_requests;
            out.worker_respawns += s.worker_respawns;
            out.request_timeouts += s.request_timeouts;
            out.request_retries += s.request_retries;
            out.request_redirects += s.request_redirects;
            out.shard_restarts += s.shard_restarts;
            out.fft_threads = out.fft_threads.max(s.fft_threads);
            out.blocked_fft |= s.blocked_fft;
            out.key_hits += s.key_hits;
            out.key_misses += s.key_misses;
            out.key_evictions += s.key_evictions;
            out.key_regenerations += s.key_regenerations;
            out.key_resident += s.key_resident;
            out.key_pinned += s.key_pinned;
            out.latency_samples_ms.extend_from_slice(&s.latency_samples_ms);
            out.queue_samples_ms.extend_from_slice(&s.queue_samples_ms);
            out.batch_size_samples.extend_from_slice(&s.batch_size_samples);
            out.stage.merge(&s.stage);
            merge_profiles(&mut out.plan_batch_profiles, &s.plan_batch_profiles);
            // Shards run concurrently: the cluster has been up as long as
            // its longest-lived shard.
            out.elapsed_s = out.elapsed_s.max(s.elapsed_s);
        }
        out.mean_batch_size = stats::mean(&out.batch_size_samples);
        out.p50_latency_ms = stats::percentile(&out.latency_samples_ms, 50.0);
        out.p99_latency_ms = stats::percentile(&out.latency_samples_ms, 99.0);
        out.mean_queue_ms = stats::mean(&out.queue_samples_ms);
        out.throughput_rps =
            if out.elapsed_s > 0.0 { out.requests as f64 / out.elapsed_s } else { 0.0 };
        out.bsk_bytes_per_pbs = if out.pbs_executed > 0 {
            out.bsk_bytes_streamed as f64 / out.pbs_executed as f64
        } else {
            0.0
        };
        out
    }

    /// p99 latency of one tenant, over its retained samples. `None` when
    /// the tenant has no recorded latencies.
    pub fn tenant_p99_ms(&self, session: u64) -> Option<f64> {
        let samples = self.session_latency_ms.get(&session)?;
        if samples.is_empty() {
            return None;
        }
        Some(stats::percentile(samples, 99.0))
    }

    /// The tenant with the worst p99 latency — the autoscaler's
    /// per-tenant pressure signal (one tenant's tail collapsing is
    /// invisible in the cluster p99 when its traffic share is small).
    pub fn worst_tenant_p99_ms(&self) -> Option<(u64, f64)> {
        self.session_latency_ms
            .iter()
            .filter(|(_, v)| !v.is_empty())
            .map(|(&session, v)| (session, stats::percentile(v, 99.0)))
            .max_by(|a, b| a.1.total_cmp(&b.1))
    }
}

impl Metrics {
    pub fn new() -> Self {
        Self { inner: Mutex::new(Inner::default()), started: Some(Instant::now()) }
    }

    /// Lock the sink, recovering from poisoning: a worker that panics
    /// mid-record (the fault-injection harness does this on purpose)
    /// must not cascade into panics in every later metrics call. Counter
    /// updates are single-field or append-only, so a poisoned guard's
    /// state is still consistent enough to keep serving.
    fn lock(&self) -> MutexGuard<'_, Inner> {
        self.inner.lock().unwrap_or_else(PoisonError::into_inner)
    }

    pub fn record_request(&self, session: SessionId, queue_ms: f64, latency_ms: f64) {
        let mut g = self.lock();
        g.requests += 1;
        *g.session_requests.entry(session.0).or_insert(0) += 1;
        g.queue_ms.push(queue_ms);
        g.latencies_ms.push(latency_ms);
        g.session_latencies
            .entry(session.0)
            .or_insert_with(|| {
                Reservoir::new(SESSION_SAMPLE_CAP, SESSION_RESERVOIR_SEED ^ session.0)
            })
            .push(latency_ms);
        if crate::obs::enabled() {
            // One queue-stage event per served request, so the stage
            // histogram's count reconciles against the request counter.
            g.stage.queue.record((queue_ms.max(0.0) * 1e6) as u64);
        }
    }

    pub fn record_batch(&self, size: usize, pbs: usize) {
        let mut g = self.lock();
        g.batches += 1;
        g.batch_sizes.push(size as f64);
        g.pbs_executed += pbs;
        g.last_progress = Some(Instant::now());
    }

    /// Account one collected batch splitting into `extra + 1` keyed
    /// execution sub-batches.
    pub fn record_keyed_splits(&self, extra: u64) {
        let mut g = self.lock();
        g.keyed_batch_splits += extra;
    }

    /// Account one batch execution's measured counters (key switches
    /// performed and Fourier-BSK bytes streamed).
    pub fn record_exec(&self, ks_ops: u64, bsk_bytes: u64) {
        let mut g = self.lock();
        g.ks_executed += ks_ops;
        g.bsk_bytes_streamed += bsk_bytes;
    }

    /// Merge one drained engine stage-timing set (worker success path).
    pub fn record_stage_times(&self, st: &StageHists) {
        if st.is_empty() {
            return;
        }
        let mut g = self.lock();
        g.stage.merge(st);
    }

    /// Merge one drained engine per-schedule-batch profile vector
    /// (worker success path).
    pub fn record_batch_profiles(&self, profiles: &[PlanBatchProfile]) {
        if profiles.is_empty() {
            return;
        }
        let mut g = self.lock();
        merge_profiles(&mut g.plan_batch_profiles, profiles);
    }

    /// Account one caught batch panic failing `failed` requests. Counts
    /// as progress for stall detection: a panicking shard is broken, not
    /// stuck, and the supervisor handles it through the failure path.
    pub fn record_exec_failure(&self, failed: u64) {
        let mut g = self.lock();
        g.exec_failures += 1;
        g.failed_requests += failed;
        g.last_progress = Some(Instant::now());
    }

    /// Account one in-place worker engine rebuild after a caught panic.
    pub fn record_worker_respawn(&self) {
        let mut g = self.lock();
        g.worker_respawns += 1;
    }

    /// Account one ticket expiring before its response arrived.
    pub fn record_timeout(&self) {
        let mut g = self.lock();
        g.request_timeouts += 1;
    }

    /// Time since a worker last completed or failed a batch (since
    /// startup if none has yet) — the supervisor's queue-age signal.
    pub fn time_since_progress(&self) -> Duration {
        let last = self.lock().last_progress;
        match (last, self.started) {
            (Some(t), _) => t.elapsed(),
            (None, Some(s)) => s.elapsed(),
            (None, None) => Duration::ZERO,
        }
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let g = self.lock();
        let elapsed = self.started.map(|s| s.elapsed().as_secs_f64()).unwrap_or(0.0);
        MetricsSnapshot {
            requests: g.requests,
            batches: g.batches,
            pbs_executed: g.pbs_executed,
            mean_batch_size: stats::mean(g.batch_sizes.samples()),
            p50_latency_ms: stats::percentile(g.latencies_ms.samples(), 50.0),
            p99_latency_ms: stats::percentile(g.latencies_ms.samples(), 99.0),
            mean_queue_ms: stats::mean(g.queue_ms.samples()),
            throughput_rps: if elapsed > 0.0 { g.requests as f64 / elapsed } else { 0.0 },
            elapsed_s: elapsed,
            ks_executed: g.ks_executed,
            bsk_bytes_streamed: g.bsk_bytes_streamed,
            bsk_bytes_per_pbs: if g.pbs_executed > 0 {
                g.bsk_bytes_streamed as f64 / g.pbs_executed as f64
            } else {
                0.0
            },
            keyed_batch_splits: g.keyed_batch_splits,
            session_requests: g.session_requests.clone(),
            session_latency_ms: g
                .session_latencies
                .iter()
                .map(|(&session, r)| (session, r.samples().to_vec()))
                .collect(),
            qos_throttled: 0,
            qos_queue_rejections: 0,
            autoscale_ups: 0,
            autoscale_downs: 0,
            exec_failures: g.exec_failures,
            failed_requests: g.failed_requests,
            worker_respawns: g.worker_respawns,
            request_timeouts: g.request_timeouts,
            request_retries: 0,
            request_redirects: 0,
            shard_restarts: 0,
            fft_threads: 0,
            blocked_fft: false,
            key_hits: 0,
            key_misses: 0,
            key_evictions: 0,
            key_regenerations: 0,
            key_resident: 0,
            key_pinned: 0,
            latency_samples_ms: g.latencies_ms.samples().to_vec(),
            queue_samples_ms: g.queue_ms.samples().to_vec(),
            batch_size_samples: g.batch_sizes.samples().to_vec(),
            stage: g.stage.clone(),
            plan_batch_profiles: g.plan_batch_profiles.clone(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_aggregates() {
        let m = Metrics::new();
        m.record_request(SessionId(3), 1.0, 10.0);
        m.record_request(SessionId(3), 3.0, 30.0);
        m.record_batch(2, 14);
        m.record_exec(4, 7000);
        let s = m.snapshot();
        assert_eq!(s.requests, 2);
        assert_eq!(s.batches, 1);
        assert_eq!(s.pbs_executed, 14);
        assert_eq!(s.ks_executed, 4);
        assert_eq!(s.mean_batch_size, 2.0);
        assert_eq!(s.mean_queue_ms, 2.0);
        assert!(s.p50_latency_ms >= 10.0 && s.p99_latency_ms <= 30.0);
        assert_eq!(s.bsk_bytes_streamed, 7000);
        assert!((s.bsk_bytes_per_pbs - 500.0).abs() < 1e-9);
        assert_eq!(s.latency_samples_ms, vec![10.0, 30.0]);
        assert_eq!(s.batch_size_samples, vec![2.0]);
        assert_eq!(s.session_requests.get(&3), Some(&2));
        assert_eq!(s.keyed_batch_splits, 0);
    }

    #[test]
    fn merge_percentiles_equal_concatenated_samples() {
        // Two shards with skewed latency distributions: the merged p50/p99
        // must equal percentiles over the concatenation, which differs
        // from any combination of the per-shard percentiles.
        let a_lat = [1.0, 2.0, 3.0, 4.0];
        let b_lat = [100.0, 200.0];
        let mk = |lats: &[f64], queues: f64| {
            let m = Metrics::new();
            for &l in lats {
                m.record_request(SessionId(0), queues, l);
            }
            m.record_batch(lats.len(), 3 * lats.len());
            m.snapshot()
        };
        let a = mk(&a_lat, 0.5);
        let b = mk(&b_lat, 1.5);
        let merged = MetricsSnapshot::merge(&[a.clone(), b.clone()]);
        let mut all: Vec<f64> = a_lat.to_vec();
        all.extend_from_slice(&b_lat);
        assert_eq!(merged.requests, 6);
        assert_eq!(merged.batches, 2);
        assert_eq!(merged.pbs_executed, 18);
        assert_eq!(merged.latency_samples_ms.len(), 6);
        assert!((merged.p50_latency_ms - crate::util::stats::percentile(&all, 50.0)).abs() < 1e-12);
        assert!((merged.p99_latency_ms - crate::util::stats::percentile(&all, 99.0)).abs() < 1e-12);
        // A mean of the two per-shard p99s would be way off the truth.
        let naive = (a.p99_latency_ms + b.p99_latency_ms) / 2.0;
        assert!((merged.p99_latency_ms - naive).abs() > 1.0, "merge must not average percentiles");
        // Mean batch size over concatenated batch samples: (4 + 2) / 2.
        assert!((merged.mean_batch_size - 3.0).abs() < 1e-12);
        // Mean queue: (4 * 0.5 + 2 * 1.5) / 6.
        assert!((merged.mean_queue_ms - (4.0 * 0.5 + 2.0 * 1.5) / 6.0).abs() < 1e-12);
        // Per-tenant counts sum across shards.
        assert_eq!(merged.session_requests.get(&0), Some(&6));
    }

    #[test]
    fn merge_weights_bsk_per_pbs_by_pbs_count() {
        // Shard A: 10 PBS at 100 B/PBS; shard B: 1 PBS at 1 B/PBS. The
        // pbs-weighted mean is 1001/11 ~ 91, not the 50.5 mean-of-ratios.
        let a = MetricsSnapshot {
            pbs_executed: 10,
            bsk_bytes_streamed: 1000,
            bsk_bytes_per_pbs: 100.0,
            ..Default::default()
        };
        let b = MetricsSnapshot {
            pbs_executed: 1,
            bsk_bytes_streamed: 1,
            bsk_bytes_per_pbs: 1.0,
            ..Default::default()
        };
        let merged = MetricsSnapshot::merge(&[a, b]);
        assert_eq!(merged.pbs_executed, 11);
        assert_eq!(merged.bsk_bytes_streamed, 1001);
        assert!((merged.bsk_bytes_per_pbs - 1001.0 / 11.0).abs() < 1e-12);
        let mean_of_ratios = (100.0 + 1.0) / 2.0;
        assert!((merged.bsk_bytes_per_pbs - mean_of_ratios).abs() > 1.0);
    }

    #[test]
    fn merge_sums_tenant_and_key_store_counters() {
        let mut a = MetricsSnapshot::default();
        a.keyed_batch_splits = 2;
        a.session_requests = [(1u64, 3u64), (2, 1)].into_iter().collect();
        a.key_hits = 5;
        a.key_misses = 2;
        a.key_evictions = 1;
        a.key_regenerations = 1;
        a.key_resident = 2;
        let mut b = MetricsSnapshot::default();
        b.keyed_batch_splits = 1;
        b.session_requests = [(2u64, 4u64), (7, 2)].into_iter().collect();
        b.key_hits = 1;
        b.key_misses = 3;
        b.key_resident = 3;
        let merged = MetricsSnapshot::merge(&[a, b]);
        assert_eq!(merged.keyed_batch_splits, 3);
        assert_eq!(
            merged.session_requests,
            [(1u64, 3u64), (2, 5), (7, 2)].into_iter().collect()
        );
        assert_eq!(
            (merged.key_hits, merged.key_misses, merged.key_evictions, merged.key_regenerations),
            (6, 5, 1, 1)
        );
        assert_eq!(merged.key_resident, 5);
    }

    #[test]
    fn per_tenant_latencies_merge_exactly_and_yield_tenant_p99() {
        // Two shards serving overlapping tenants: the merged per-tenant
        // sample sets must be the concatenation per key, and tenant p99
        // must be computed over that union — not per shard, not global.
        let mk = |records: &[(u64, f64)]| {
            let m = Metrics::new();
            for &(session, lat) in records {
                m.record_request(SessionId(session), 0.0, lat);
            }
            m.snapshot()
        };
        let a = mk(&[(1, 10.0), (1, 20.0), (2, 5.0)]);
        let b = mk(&[(1, 100.0), (3, 7.0)]);
        assert_eq!(a.session_latency_ms.get(&1).unwrap(), &vec![10.0, 20.0]);
        let merged = MetricsSnapshot::merge(&[a, b]);
        assert_eq!(merged.session_latency_ms.get(&1).unwrap(), &vec![10.0, 20.0, 100.0]);
        assert_eq!(merged.session_latency_ms.get(&2).unwrap(), &vec![5.0]);
        assert_eq!(merged.session_latency_ms.get(&3).unwrap(), &vec![7.0]);
        let p99 = merged.tenant_p99_ms(1).unwrap();
        assert!(
            (p99 - stats::percentile(&[10.0, 20.0, 100.0], 99.0)).abs() < 1e-12,
            "tenant p99 over the merged union"
        );
        assert_eq!(merged.tenant_p99_ms(9), None);
        // Tenant 1's tail dominates; the worst-tenant probe finds it.
        let (worst, worst_p99) = merged.worst_tenant_p99_ms().unwrap();
        assert_eq!(worst, 1);
        assert!((worst_p99 - p99).abs() < 1e-12);
        // The global p99 is computed over ALL 5 samples — sanity that the
        // per-tenant view is genuinely finer.
        assert!(merged.p99_latency_ms > merged.tenant_p99_ms(3).unwrap());
    }

    #[test]
    fn per_tenant_reservoirs_stay_bounded_and_deterministic() {
        let m = Metrics::new();
        for i in 0..10_000u64 {
            m.record_request(SessionId(i % 3), 0.0, (i % 101) as f64);
        }
        let s = m.snapshot();
        for t in 0..3u64 {
            assert_eq!(s.session_latency_ms.get(&t).unwrap().len(), SESSION_SAMPLE_CAP);
        }
        let m2 = Metrics::new();
        for i in 0..10_000u64 {
            m2.record_request(SessionId(i % 3), 0.0, (i % 101) as f64);
        }
        assert_eq!(
            m2.snapshot().session_latency_ms,
            s.session_latency_ms,
            "identical record streams retain identical per-tenant subsamples"
        );
    }

    #[test]
    fn merge_sums_qos_and_autoscale_counters() {
        let a = MetricsSnapshot {
            qos_throttled: 4,
            qos_queue_rejections: 2,
            autoscale_ups: 1,
            ..Default::default()
        };
        let b = MetricsSnapshot {
            qos_throttled: 1,
            autoscale_downs: 1,
            ..Default::default()
        };
        let merged = MetricsSnapshot::merge(&[a, b]);
        assert_eq!(merged.qos_throttled, 5);
        assert_eq!(merged.qos_queue_rejections, 2);
        assert_eq!(merged.autoscale_ups, 1);
        assert_eq!(merged.autoscale_downs, 1);
    }

    #[test]
    fn poisoned_sink_keeps_recording_instead_of_cascading_panics() {
        use std::sync::Arc;
        let m = Arc::new(Metrics::new());
        m.record_request(SessionId(1), 0.0, 5.0);
        // Poison the mutex: panic while holding the guard, exactly what a
        // worker dying inside a record call would do.
        let m2 = m.clone();
        let t = std::thread::spawn(move || {
            let _g = m2.inner.lock().unwrap();
            panic!("injected panic while holding the metrics lock");
        });
        assert!(t.join().is_err(), "the poisoning thread must have panicked");
        assert!(m.inner.lock().is_err(), "the mutex really is poisoned");
        // Every entry point must recover the guard, not propagate poison.
        m.record_request(SessionId(1), 0.0, 7.0);
        m.record_batch(2, 4);
        m.record_exec(1, 10);
        m.record_exec_failure(3);
        m.record_worker_respawn();
        m.record_timeout();
        m.record_keyed_splits(1);
        let _ = m.time_since_progress();
        let s = m.snapshot();
        assert_eq!(s.requests, 2, "pre- and post-poison records both visible");
        assert_eq!(s.exec_failures, 1);
        assert_eq!(s.failed_requests, 3);
        assert_eq!(s.worker_respawns, 1);
        assert_eq!(s.request_timeouts, 1);
    }

    #[test]
    fn merge_sums_failure_and_recovery_counters() {
        let a = MetricsSnapshot {
            exec_failures: 2,
            failed_requests: 5,
            worker_respawns: 2,
            request_timeouts: 1,
            request_retries: 3,
            request_redirects: 1,
            shard_restarts: 1,
            ..Default::default()
        };
        let b = MetricsSnapshot {
            exec_failures: 1,
            failed_requests: 1,
            worker_respawns: 1,
            request_timeouts: 2,
            ..Default::default()
        };
        let merged = MetricsSnapshot::merge(&[a, b]);
        assert_eq!(merged.exec_failures, 3);
        assert_eq!(merged.failed_requests, 6);
        assert_eq!(merged.worker_respawns, 3);
        assert_eq!(merged.request_timeouts, 3);
        assert_eq!(merged.request_retries, 3);
        assert_eq!(merged.request_redirects, 1);
        assert_eq!(merged.shard_restarts, 1);
    }

    #[test]
    fn merge_takes_max_threads_and_ors_blocked_fft() {
        let a = MetricsSnapshot { fft_threads: 4, blocked_fft: false, ..Default::default() };
        let b = MetricsSnapshot { fft_threads: 1, blocked_fft: true, ..Default::default() };
        let merged = MetricsSnapshot::merge(&[a, b]);
        assert_eq!(merged.fft_threads, 4, "cluster view reports the widest shard pool");
        assert!(merged.blocked_fft, "any blocked shard marks the cluster blocked");
    }

    #[test]
    fn sample_memory_is_bounded_under_a_million_requests() {
        // The soak regression the reservoirs exist for: a million served
        // requests must leave the snapshot's sample vectors at the cap,
        // not a million entries, while every counter stays exact.
        let m = Metrics::new();
        for i in 0..1_000_000u64 {
            m.record_request(SessionId(i % 7), (i % 13) as f64, (i % 97) as f64);
        }
        m.record_batch(8, 16);
        let s = m.snapshot();
        assert_eq!(s.requests, 1_000_000, "counters stay exact");
        assert_eq!(s.latency_samples_ms.len(), SAMPLE_CAP, "latency samples capped");
        assert_eq!(s.queue_samples_ms.len(), SAMPLE_CAP, "queue samples capped");
        assert!(s.latency_samples_ms.iter().all(|&v| (0.0..97.0).contains(&v)));
        assert_eq!(s.session_requests.values().sum::<u64>(), 1_000_000);
        // Determinism: an identical record stream retains identical samples.
        let m2 = Metrics::new();
        for i in 0..1_000_000u64 {
            m2.record_request(SessionId(i % 7), (i % 13) as f64, (i % 97) as f64);
        }
        assert_eq!(m2.snapshot().latency_samples_ms, s.latency_samples_ms);
    }

    #[test]
    fn merge_rolls_up_stage_hists_and_batch_profiles() {
        let mut a = MetricsSnapshot::default();
        a.stage.keyswitch.record(100);
        a.plan_batch_profiles =
            vec![PlanBatchProfile { requests: 2, ks_calls: 4, ..Default::default() }];
        let mut b = MetricsSnapshot::default();
        b.stage.keyswitch.record(200);
        b.stage.fft.record(50);
        b.plan_batch_profiles = vec![
            PlanBatchProfile { requests: 1, ks_calls: 2, ..Default::default() },
            PlanBatchProfile { pbs: 3, ..Default::default() },
        ];
        let merged = MetricsSnapshot::merge(&[a, b]);
        assert_eq!(merged.stage.keyswitch.count(), 2);
        assert_eq!(merged.stage.fft.count(), 1);
        assert_eq!(merged.plan_batch_profiles.len(), 2);
        assert_eq!(merged.plan_batch_profiles[0].ks_calls, 6);
        assert_eq!(merged.plan_batch_profiles[1].pbs, 3);
    }

    #[test]
    fn merge_of_empty_and_default_metrics_is_zeroed() {
        assert_eq!(MetricsSnapshot::merge(&[]).requests, 0);
        let m = Metrics::default(); // same as new(): live clock, no samples
        let merged = MetricsSnapshot::merge(&[m.snapshot()]);
        assert_eq!(merged.requests, 0);
        assert_eq!(merged.bsk_bytes_per_pbs, 0.0);
        assert_eq!(merged.p99_latency_ms, 0.0);
        assert!(merged.session_requests.is_empty());
    }
}
