//! Serving metrics: latency distribution + throughput counters.

use std::sync::Mutex;
use std::time::Instant;

use crate::util::stats;

#[derive(Debug, Default)]
struct Inner {
    latencies_ms: Vec<f64>,
    queue_ms: Vec<f64>,
    batches: usize,
    batch_sizes: Vec<f64>,
    requests: usize,
    pbs_executed: usize,
    ks_executed: u64,
    bsk_bytes_streamed: u64,
}

/// Thread-safe metrics sink shared by batcher and workers.
#[derive(Debug, Default)]
pub struct Metrics {
    inner: Mutex<Inner>,
    started: Option<Instant>,
}

#[derive(Debug, Clone, Default)]
pub struct MetricsSnapshot {
    pub requests: usize,
    pub batches: usize,
    pub pbs_executed: usize,
    pub mean_batch_size: f64,
    pub p50_latency_ms: f64,
    pub p99_latency_ms: f64,
    pub mean_queue_ms: f64,
    pub throughput_rps: f64,
    pub elapsed_s: f64,
    /// Key switches the workers actually executed — with the plan-driven
    /// path this equals `ks_dedup.after x requests`, the measured
    /// realization of the compiler's KS-dedup (cross-check against
    /// `arch::sim::SimResult::ks_count`).
    pub ks_executed: u64,
    /// Total Fourier-BSK bytes the workers' blind rotations streamed.
    pub bsk_bytes_streamed: u64,
    /// Amortized BSK bytes per executed PBS — the key-reuse metric: equals
    /// one full BSK stream per PBS when batches degenerate to size 1 and
    /// shrinks ~Bx when dynamic batches of B fuse their sweeps.
    pub bsk_bytes_per_pbs: f64,
}

impl Metrics {
    pub fn new() -> Self {
        Self { inner: Mutex::new(Inner::default()), started: Some(Instant::now()) }
    }

    pub fn record_request(&self, queue_ms: f64, latency_ms: f64) {
        let mut g = self.inner.lock().unwrap();
        g.requests += 1;
        g.queue_ms.push(queue_ms);
        g.latencies_ms.push(latency_ms);
    }

    pub fn record_batch(&self, size: usize, pbs: usize) {
        let mut g = self.inner.lock().unwrap();
        g.batches += 1;
        g.batch_sizes.push(size as f64);
        g.pbs_executed += pbs;
    }

    /// Account one batch execution's measured counters (key switches
    /// performed and Fourier-BSK bytes streamed).
    pub fn record_exec(&self, ks_ops: u64, bsk_bytes: u64) {
        let mut g = self.inner.lock().unwrap();
        g.ks_executed += ks_ops;
        g.bsk_bytes_streamed += bsk_bytes;
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let g = self.inner.lock().unwrap();
        let elapsed = self.started.map(|s| s.elapsed().as_secs_f64()).unwrap_or(0.0);
        MetricsSnapshot {
            requests: g.requests,
            batches: g.batches,
            pbs_executed: g.pbs_executed,
            mean_batch_size: stats::mean(&g.batch_sizes),
            p50_latency_ms: stats::percentile(&g.latencies_ms, 50.0),
            p99_latency_ms: stats::percentile(&g.latencies_ms, 99.0),
            mean_queue_ms: stats::mean(&g.queue_ms),
            throughput_rps: if elapsed > 0.0 { g.requests as f64 / elapsed } else { 0.0 },
            elapsed_s: elapsed,
            ks_executed: g.ks_executed,
            bsk_bytes_streamed: g.bsk_bytes_streamed,
            bsk_bytes_per_pbs: if g.pbs_executed > 0 {
                g.bsk_bytes_streamed as f64 / g.pbs_executed as f64
            } else {
                0.0
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_aggregates() {
        let m = Metrics::new();
        m.record_request(1.0, 10.0);
        m.record_request(3.0, 30.0);
        m.record_batch(2, 14);
        m.record_exec(4, 7000);
        let s = m.snapshot();
        assert_eq!(s.requests, 2);
        assert_eq!(s.batches, 1);
        assert_eq!(s.pbs_executed, 14);
        assert_eq!(s.ks_executed, 4);
        assert_eq!(s.mean_batch_size, 2.0);
        assert_eq!(s.mean_queue_ms, 2.0);
        assert!(s.p50_latency_ms >= 10.0 && s.p99_latency_ms <= 30.0);
        assert_eq!(s.bsk_bytes_streamed, 7000);
        assert!((s.bsk_bytes_per_pbs - 500.0).abs() < 1e-9);
    }
}
