//! The coordinator proper: request intake -> dynamic batcher -> worker
//! pool -> responses, over either PBS backend.
//!
//! Thread topology: callers hold a cheap `Coordinator` handle; a dispatch
//! thread owns the batcher; worker threads own their execution engines
//! (the `xla` crate's PJRT client is Rc-based/non-Send, so each XLA
//! worker constructs its own backend from the artifact dir + cloned keys
//! inside its thread).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::batcher::DynamicBatcher;
use super::metrics::Metrics;
use crate::compiler::{Engine, NativePbsBackend, PbsBackend};
use crate::ir::Program;
use crate::tfhe::{LweCiphertext, ServerKeys};

/// Which PBS backend workers run.
#[derive(Debug, Clone)]
pub enum BackendKind {
    /// Pure-Rust TFHE.
    Native,
    /// AOT JAX/Pallas artifacts via PJRT (artifact directory).
    Xla { artifacts_dir: String },
}

#[derive(Debug, Clone)]
pub struct CoordinatorOptions {
    pub workers: usize,
    pub batch_capacity: usize,
    pub max_batch_wait: Duration,
    pub backend: BackendKind,
}

impl Default for CoordinatorOptions {
    fn default() -> Self {
        Self {
            workers: 2,
            batch_capacity: 8,
            max_batch_wait: Duration::from_millis(2),
            backend: BackendKind::Native,
        }
    }
}

struct Request {
    inputs: Vec<LweCiphertext>,
    enqueued: Instant,
    respond: Sender<Vec<LweCiphertext>>,
}

/// A running FHE model server for one compiled program.
pub struct Coordinator {
    intake: Sender<Request>,
    pub metrics: Arc<Metrics>,
    dispatch: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
    pub inflight: Arc<AtomicUsize>,
}

impl Coordinator {
    pub fn start(program: Program, keys: Arc<ServerKeys>, opts: CoordinatorOptions) -> Self {
        // Fail on the caller's thread, not inside a worker, when the
        // requested backend isn't compiled in.
        #[cfg(not(feature = "xla"))]
        if matches!(opts.backend, BackendKind::Xla { .. }) {
            panic!("XLA backend requested but built without the `xla` feature");
        }
        let metrics = Arc::new(Metrics::new());
        let inflight = Arc::new(AtomicUsize::new(0));
        let (intake_tx, intake_rx) = channel::<Request>();
        // Dispatch thread: batch then round-robin to workers.
        let (work_txs, work_rxs): (Vec<Sender<Vec<Request>>>, Vec<Receiver<Vec<Request>>>) =
            (0..opts.workers).map(|_| channel()).unzip();
        let batcher = DynamicBatcher::new(opts.batch_capacity, opts.max_batch_wait);
        let dispatch = std::thread::spawn(move || {
            let mut next = 0usize;
            loop {
                let batch = batcher.collect(&intake_rx);
                if batch.is_empty() {
                    break; // intake closed
                }
                if work_txs[next % work_txs.len()].send(batch).is_err() {
                    break;
                }
                next += 1;
            }
        });
        let workers = work_rxs
            .into_iter()
            .map(|rx| {
                let program = program.clone();
                let keys = keys.clone();
                let metrics = metrics.clone();
                let inflight = inflight.clone();
                let backend = opts.backend.clone();
                std::thread::spawn(move || match backend {
                    BackendKind::Native => {
                        let engine = Engine::new(NativePbsBackend::new(&keys));
                        worker_loop(rx, engine, &program, &metrics, &inflight);
                    }
                    #[cfg(feature = "xla")]
                    BackendKind::Xla { artifacts_dir } => {
                        let be = crate::runtime::XlaPbsBackend::new(
                            &artifacts_dir,
                            &keys.params,
                            &keys.bsk,
                            &keys.ksk,
                        )
                        .expect("xla backend");
                        let engine = Engine::new(be);
                        worker_loop(rx, engine, &program, &metrics, &inflight);
                    }
                    #[cfg(not(feature = "xla"))]
                    BackendKind::Xla { .. } => {
                        panic!("XLA backend requested but built without the `xla` feature")
                    }
                })
            })
            .collect();
        Self { intake: intake_tx, metrics, dispatch: Some(dispatch), workers, inflight }
    }

    /// Submit one encrypted query; returns the channel the response will
    /// arrive on.
    pub fn submit(&self, inputs: Vec<LweCiphertext>) -> Receiver<Vec<LweCiphertext>> {
        let (tx, rx) = channel();
        self.inflight.fetch_add(1, Ordering::SeqCst);
        self.intake
            .send(Request { inputs, enqueued: Instant::now(), respond: tx })
            .expect("coordinator stopped");
        rx
    }

    /// Graceful shutdown: close intake, drain workers.
    pub fn shutdown(mut self) {
        drop(self.intake);
        if let Some(d) = self.dispatch.take() {
            let _ = d.join();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

fn worker_loop<B: PbsBackend>(
    rx: Receiver<Vec<Request>>,
    mut engine: Engine<B>,
    program: &Program,
    metrics: &Metrics,
    inflight: &AtomicUsize,
) {
    while let Ok(batch) = rx.recv() {
        let size = batch.len();
        let pbs = program.pbs_count() * size;
        // Record up front so snapshots taken right after the last response
        // already see this batch.
        metrics.record_batch(size, pbs);
        // One fused sweep: the whole dynamic batch walks the program in
        // lockstep, so every LUT node streams the BSK once per batch
        // (key reuse) instead of once per request. Inputs are moved out
        // of the requests, not cloned.
        let (metas, inputs): (Vec<(Instant, Sender<Vec<LweCiphertext>>)>, Vec<_>) =
            batch.into_iter().map(|r| ((r.enqueued, r.respond), r.inputs)).unzip();
        let queue_ms: Vec<f64> =
            metas.iter().map(|(t, _)| t.elapsed().as_secs_f64() * 1e3).collect();
        let outs = engine.run_batch(program, &inputs);
        metrics.record_bsk_traffic(engine.take_bsk_bytes_streamed());
        for (((enqueued, respond), out), q_ms) in metas.into_iter().zip(outs).zip(queue_ms) {
            let latency_ms = enqueued.elapsed().as_secs_f64() * 1e3;
            metrics.record_request(q_ms, latency_ms);
            inflight.fetch_sub(1, Ordering::SeqCst);
            let _ = respond.send(out); // client may have gone away
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::builder::ProgramBuilder;
    use crate::ir::interp;
    use crate::params::TEST1;
    use crate::tfhe::pbs::{decrypt_message, encrypt_message};
    use crate::tfhe::SecretKeys;
    use crate::util::rng::Rng;

    fn small_program() -> Program {
        let mut b = ProgramBuilder::new("serve", 3);
        let x = b.input();
        let y = b.input();
        let s = b.add(x, y);
        let r = b.lut_fn(s, |m| (m * 2 + 1) % 16);
        b.output(r);
        b.finish()
    }

    #[test]
    fn serves_concurrent_requests_correctly() {
        let mut rng = Rng::new(31);
        let sk = SecretKeys::generate(&TEST1, &mut rng);
        let keys = Arc::new(ServerKeys::generate(&sk, &mut rng));
        let keys2 = keys.clone();
        let prog = small_program();
        let coord = Coordinator::start(
            prog.clone(),
            keys,
            CoordinatorOptions { workers: 3, batch_capacity: 4, ..Default::default() },
        );
        let queries: Vec<(u64, u64)> = (0..12).map(|i| (i % 6, (i * 3) % 6)).collect();
        let mut pending = Vec::new();
        for &(x, y) in &queries {
            let inputs =
                vec![encrypt_message(x, &sk, &mut rng), encrypt_message(y, &sk, &mut rng)];
            pending.push(coord.submit(inputs));
        }
        for (rx, &(x, y)) in pending.iter().zip(&queries) {
            let outs = rx.recv().expect("response");
            let exp = interp::eval(&prog, &[x, y]);
            assert_eq!(decrypt_message(&outs[0], &sk), exp[0], "query ({x},{y})");
        }
        let snap = coord.metrics.snapshot();
        assert_eq!(snap.requests, 12);
        assert!(snap.batches >= 3, "round-robined to several batches");
        assert_eq!(coord.inflight.load(Ordering::SeqCst), 0);
        // Key-reuse accounting: fused sweeps stream at most one full BSK
        // per PBS (exactly one when a batch degenerates to size 1).
        assert!(snap.bsk_bytes_streamed > 0);
        let full = keys2.bsk.bytes() as f64;
        assert!(
            snap.bsk_bytes_per_pbs <= full + 1.0,
            "amortized {} vs full stream {}",
            snap.bsk_bytes_per_pbs,
            full
        );
        coord.shutdown();
    }

    #[test]
    fn shutdown_is_clean_with_no_requests() {
        let mut rng = Rng::new(32);
        let sk = SecretKeys::generate(&TEST1, &mut rng);
        let keys = Arc::new(ServerKeys::generate(&sk, &mut rng));
        let coord = Coordinator::start(small_program(), keys, Default::default());
        coord.shutdown();
    }
}
