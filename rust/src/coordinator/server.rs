//! The coordinator proper: request intake -> dynamic batcher -> keyed
//! grouping -> worker pool -> responses, over either PBS backend.
//!
//! The program is compiled ONCE at startup; every worker executes the
//! shared [`CompiledPlan`] through the schedule-driven engine
//! (`Engine::run_plan_batch`), so KS-dedup and accumulator-fused blind
//! rotations are realized on the serving path and the metrics' measured
//! KS/PBS counts cross-check `arch::sim`'s costs for the same plan. The
//! legacy node-walking executor remains behind
//! [`CoordinatorOptions::legacy_exec`] as an ablation baseline.
//!
//! **Sessions and keys.** Requests are submitted *for a session*
//! ([`Coordinator::submit_for`]); a [`KeyStore`] resolves each session to
//! a [`KeyHandle`] at admission time, the dispatch thread groups every
//! collected batch by key handle ([`super::batcher::group_batch`]), and a
//! worker executes each keyed sub-batch under exactly one key set —
//! rebinding its native backend (`NativePbsBackend::set_keys`) when
//! consecutive sub-batches belong to different tenants. The single-tenant
//! path ([`Coordinator::start`], wrapping [`StaticKeys`]) resolves every
//! session to one handle, so batches never split and behavior is
//! bit-identical to the pre-session API.
//!
//! Thread topology: callers hold a cheap `Coordinator` handle; a dispatch
//! thread owns the batcher; worker threads own their execution engines
//! (the `xla` crate's PJRT client is Rc-based/non-Send, so each XLA
//! worker constructs its own backend from the artifact dir + resolved
//! keys inside its thread; the XLA backend cannot rebind keys, so it
//! requires a single-key store).
//!
//! **Failure semantics.** Each keyed sub-batch executes under a
//! `catch_unwind` boundary: a panicking backend fails only that batch's
//! requests — every stranded [`Ticket`] resolves to a typed
//! [`RequestError::ExecFailed`] instead of a hung channel — and the
//! worker drops its (possibly inconsistent) engine and rebuilds it from
//! the next sub-batch's key handle (an in-place respawn, counted in
//! [`MetricsSnapshot::worker_respawns`]). Measured batch/KS/PBS counters
//! are recorded only for batches that *succeed*, so the
//! measured-vs-`arch::sim` cross-check invariants survive injected
//! faults. Supervised coordinators (the cluster) additionally receive
//! every failed request on a [`FailureSink`] for retry on another shard.

use std::fmt;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::batcher::{group_batch, DynamicBatcher};
use super::metrics::{Metrics, MetricsSnapshot};
use crate::compiler::{self, CompiledPlan, Engine, EngineOptions, NativePbsBackend, PbsBackend};
use crate::obs;
use crate::ir::Program;
use crate::runtime::faults::{FaultPlan, FaultyBackend};
use crate::tenant::{KeyHandle, KeyStore, SessionId, StaticKeys};
use crate::tfhe::{LweCiphertext, ServerKeys};

/// Which PBS backend workers run.
#[derive(Debug, Clone)]
pub enum BackendKind {
    /// Pure-Rust TFHE.
    Native,
    /// AOT JAX/Pallas artifacts via PJRT (artifact directory).
    Xla { artifacts_dir: String },
    /// Pure-Rust TFHE behind a deterministic fault-injection plan
    /// (`serve --chaos` and the chaos tests). The plain `Native` arm
    /// never touches the plan, so fault-free serving pays nothing.
    NativeChaos { faults: Arc<FaultPlan> },
}

#[derive(Debug, Clone)]
pub struct CoordinatorOptions {
    pub workers: usize,
    pub batch_capacity: usize,
    pub max_batch_wait: Duration,
    pub backend: BackendKind,
    /// Schedule batch capacity for the compiled plan (Fig. 9).
    pub plan_capacity: usize,
    /// Run the legacy node-walking executor instead of the compiled plan
    /// (ablation / debugging; the plan path is the default).
    pub legacy_exec: bool,
    /// Bound on outstanding requests: once this many submissions have not
    /// yet been answered, [`Coordinator::submit`] sheds load with
    /// [`SubmitError::QueueFull`] instead of queueing without limit.
    /// `None` keeps the historical unbounded intake. The cluster's shared
    /// admission queue (`crate::cluster`) composes with this per-shard
    /// bound.
    pub max_queue_depth: Option<usize>,
    /// Worker threads for each native backend's column-parallel blind
    /// rotation (`serve --fft-threads`); 1 = sequential. Outputs are
    /// bitwise-identical for every value, so this is purely a latency
    /// knob. The XLA backend ignores it.
    pub fft_threads: usize,
}

impl Default for CoordinatorOptions {
    fn default() -> Self {
        Self {
            workers: 2,
            batch_capacity: 8,
            max_batch_wait: Duration::from_millis(2),
            backend: BackendKind::Native,
            plan_capacity: 48,
            legacy_exec: false,
            max_queue_depth: None,
            fft_threads: 1,
        }
    }
}

/// Error returned by [`Coordinator::submit`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SubmitError {
    /// Intake has closed ([`Coordinator::shutdown`] ran).
    Stopped,
    /// `max_queue_depth` requests are already outstanding — shed load and
    /// let the client retry (or route to another shard).
    QueueFull,
    /// The key store could not resolve this session's keys (backing
    /// fetch down, or an injected fault) — the request was never
    /// enqueued; the cluster redirects it to another shard.
    ResolveFailed,
}

impl fmt::Display for SubmitError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SubmitError::Stopped => f.write_str("coordinator stopped"),
            SubmitError::QueueFull => f.write_str("coordinator queue full"),
            SubmitError::ResolveFailed => f.write_str("session key resolution failed"),
        }
    }
}

impl std::error::Error for SubmitError {}

/// Typed per-request failure delivered through a [`Ticket`]. Every
/// admitted request terminates with output ciphertexts or one of these —
/// never a silently hung channel.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum RequestError {
    /// The batch this request was grouped into panicked in the backend;
    /// the worker caught it at the batch boundary and respawned.
    ExecFailed { reason: String },
    /// The ticket's deadline expired before a response arrived. The
    /// request may still complete server-side; its result is discarded.
    RequestTimeout,
    /// The serving shard went away (hard kill or dropped response path)
    /// before answering.
    ShardLost,
    /// A retry path could not re-resolve the session's keys.
    ResolveFailed { reason: String },
}

impl fmt::Display for RequestError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            RequestError::ExecFailed { reason } => write!(f, "batch execution failed: {reason}"),
            RequestError::RequestTimeout => f.write_str("request deadline expired"),
            RequestError::ShardLost => f.write_str("serving shard lost"),
            RequestError::ResolveFailed { reason } => {
                write!(f, "session key resolution failed: {reason}")
            }
        }
    }
}

impl std::error::Error for RequestError {}

/// What travels back on a response channel.
pub(crate) type Response = Result<Vec<LweCiphertext>, RequestError>;

/// A pending response. [`Ticket::wait`] blocks until the request
/// terminates: output ciphertexts, a typed [`RequestError`], or — when
/// the ticket carries a deadline ([`Coordinator::submit_with_deadline`])
/// — [`RequestError::RequestTimeout`] once the deadline passes.
#[derive(Debug)]
pub struct Ticket {
    rx: Receiver<Response>,
    deadline: Option<Instant>,
    metrics: Arc<Metrics>,
    /// Request trace id (0 when tracing was disabled at admission).
    trace: u64,
}

impl Ticket {
    pub(crate) fn new(
        rx: Receiver<Response>,
        deadline: Option<Instant>,
        metrics: Arc<Metrics>,
        trace: u64,
    ) -> Self {
        Self { rx, deadline, metrics, trace }
    }

    /// Wait for this request to terminate.
    pub fn wait(&self) -> Result<Vec<LweCiphertext>, RequestError> {
        let out = match self.deadline {
            None => match self.rx.recv() {
                Ok(r) => r,
                Err(_) => Err(RequestError::ShardLost),
            },
            Some(d) => match self.rx.recv_timeout(d.saturating_duration_since(Instant::now())) {
                Ok(r) => r,
                Err(RecvTimeoutError::Timeout) => {
                    self.metrics.record_timeout();
                    Err(RequestError::RequestTimeout)
                }
                Err(RecvTimeoutError::Disconnected) => Err(RequestError::ShardLost),
            },
        };
        if self.trace != 0 {
            // Terminal instant named by outcome, then close the async
            // request span minted at admission. A re-waited ticket (the
            // `recv` alias can be called again after a timeout) only
            // re-records if tracing is still enabled; span-tree checks
            // wait each ticket exactly once.
            let name = match &out {
                Ok(_) => "served",
                Err(RequestError::RequestTimeout) => "timeout",
                Err(RequestError::ShardLost) => "shard_lost",
                Err(RequestError::ExecFailed { .. }) => "exec_failed",
                Err(RequestError::ResolveFailed { .. }) => "resolve_failed",
            };
            obs::trace::instant(name, self.trace);
            obs::trace::async_end("request", self.trace);
        }
        out
    }

    /// Alias for [`Self::wait`], mirroring the channel API this evolved
    /// from.
    pub fn recv(&self) -> Result<Vec<LweCiphertext>, RequestError> {
        self.wait()
    }
}

/// One request the worker could not serve, forwarded to the cluster
/// supervisor for bounded retry on another shard (safe: plan execution
/// is deterministic, and a request fails *before* producing any
/// response, so a retry can never double-answer).
pub(crate) struct FailedRequest {
    pub(crate) shard: usize,
    pub(crate) generation: u64,
    pub(crate) session: SessionId,
    pub(crate) inputs: Vec<LweCiphertext>,
    pub(crate) respond: Sender<Response>,
    pub(crate) retries: u32,
    pub(crate) reason: String,
    /// Trace id carried across the retry so the request's whole journey
    /// (fail, redirect, retry, terminal) shares one async span.
    pub(crate) trace: u64,
}

/// Where a supervised coordinator's workers report failed requests,
/// tagged with the shard id and topology generation they belong to.
#[derive(Clone)]
pub(crate) struct FailureSink {
    pub(crate) shard: usize,
    pub(crate) generation: u64,
    pub(crate) tx: Sender<FailedRequest>,
}

/// Atomically claim one slot of a bounded (or unbounded, `depth: None`)
/// admission counter; `false` means the bound is reached and nothing was
/// claimed. Shared by [`Coordinator::submit`] and the cluster's admission
/// queue — the compare loop guarantees concurrent claimers never exceed
/// `depth`.
pub(crate) fn try_claim_slot(counter: &AtomicUsize, depth: Option<usize>) -> bool {
    match depth {
        Some(d) => counter
            .fetch_update(Ordering::SeqCst, Ordering::SeqCst, |n| (n < d).then_some(n + 1))
            .is_ok(),
        None => {
            counter.fetch_add(1, Ordering::SeqCst);
            true
        }
    }
}

struct Request {
    session: SessionId,
    /// Key set resolved at admission time. The handle's `Arc` keeps the
    /// keys alive through execution even if the store evicts the entry
    /// meanwhile.
    handle: KeyHandle,
    inputs: Vec<LweCiphertext>,
    enqueued: Instant,
    respond: Sender<Response>,
    /// How many times the cluster supervisor has already re-dispatched
    /// this request after a failure (0 on first submission).
    retries: u32,
    /// Trace id minted at admission (0 when tracing was disabled).
    trace: u64,
}

/// One keyed execution sub-batch: every request shares `handle`'s keys.
struct WorkItem {
    handle: KeyHandle,
    requests: Vec<Request>,
}

/// A running FHE model server for one compiled program.
pub struct Coordinator {
    intake: Option<Sender<Request>>,
    pub metrics: Arc<Metrics>,
    store: Arc<dyn KeyStore>,
    dispatch: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
    pub inflight: Arc<AtomicUsize>,
    plan: Arc<CompiledPlan>,
    max_queue_depth: Option<usize>,
    fft_threads: usize,
    /// Hard-stop flag ([`Self::kill`]): workers fail remaining work with
    /// [`RequestError::ShardLost`] instead of executing it.
    killed: Arc<AtomicBool>,
}

impl Coordinator {
    /// Single-tenant compat constructor: every request executes under one
    /// global key set (a [`StaticKeys`] wrapper around `keys`).
    pub fn start(program: Program, keys: Arc<ServerKeys>, opts: CoordinatorOptions) -> Self {
        Self::start_with_store(program, Arc::new(StaticKeys::new(keys)), opts)
    }

    /// Start from an already-compiled plan under one global key set
    /// (compat: wraps `keys` in [`StaticKeys`]).
    pub fn start_with_plan(
        plan: Arc<CompiledPlan>,
        keys: Arc<ServerKeys>,
        opts: CoordinatorOptions,
    ) -> Self {
        Self::start_with_plan_store(plan, Arc::new(StaticKeys::new(keys)), opts)
    }

    /// Start a session-keyed coordinator: requests are resolved through
    /// `store` per session.
    pub fn start_with_store(
        program: Program,
        store: Arc<dyn KeyStore>,
        opts: CoordinatorOptions,
    ) -> Self {
        // One compiled plan, shared by every worker (and available to
        // callers for sim cross-checks via [`Self::plan`]).
        let plan = Arc::new(compiler::compile(&program, store.params(), opts.plan_capacity));
        Self::start_with_plan_store(plan, store, opts)
    }

    /// Start from an already-compiled plan and a session key store. This
    /// is how the cluster layer (`crate::cluster`) replicates one program
    /// across N shards without compiling N times: every shard's workers
    /// walk the very same [`CompiledPlan`] artifact against their
    /// shard-local store.
    pub fn start_with_plan_store(
        plan: Arc<CompiledPlan>,
        store: Arc<dyn KeyStore>,
        opts: CoordinatorOptions,
    ) -> Self {
        Self::start_supervised(plan, store, opts, None)
    }

    /// [`Self::start_with_plan_store`] plus a [`FailureSink`]: requests
    /// whose batch panics are forwarded to the sink (for the cluster
    /// supervisor to retry elsewhere) instead of failing terminally on
    /// their tickets.
    pub(crate) fn start_supervised(
        plan: Arc<CompiledPlan>,
        store: Arc<dyn KeyStore>,
        opts: CoordinatorOptions,
        sink: Option<FailureSink>,
    ) -> Self {
        // Fail on the caller's thread, not inside a worker, when the
        // requested backend isn't compiled in.
        #[cfg(not(feature = "xla"))]
        if matches!(opts.backend, BackendKind::Xla { .. }) {
            panic!("XLA backend requested but built without the `xla` feature");
        }
        // Same principle for key stores the backend cannot serve: the XLA
        // backend bakes keys into device buffers and cannot rebind per
        // keyed sub-batch, so a multi-key store must be rejected here, at
        // construction — a worker discovering it mid-serving would turn a
        // configuration mistake into per-batch `ExecFailed` churn.
        if matches!(opts.backend, BackendKind::Xla { .. }) {
            assert!(
                store.is_single_key(),
                "the XLA backend cannot rebind server keys per sub-batch; \
                 it requires a single-key store (StaticKeys)"
            );
        }
        assert!(opts.batch_capacity >= 1, "batch_capacity must be >= 1");
        assert_eq!(
            plan.params.name,
            store.params().name,
            "compiled plan and key store use different parameter sets"
        );
        let metrics = Arc::new(Metrics::new());
        let inflight = Arc::new(AtomicUsize::new(0));
        let killed = Arc::new(AtomicBool::new(false));
        let (intake_tx, intake_rx) = channel::<Request>();
        // Dispatch thread: batch, group by key handle, round-robin the
        // keyed sub-batches to workers.
        let (work_txs, work_rxs): (Vec<Sender<WorkItem>>, Vec<Receiver<WorkItem>>) =
            (0..opts.workers).map(|_| channel()).unzip();
        let batcher = DynamicBatcher::new(opts.batch_capacity, opts.max_batch_wait);
        let dispatch_metrics = metrics.clone();
        let dispatch = std::thread::spawn(move || {
            let mut next = 0usize;
            loop {
                let batch = batcher.collect(&intake_rx);
                if batch.is_empty() {
                    break; // intake closed
                }
                let groups =
                    group_batch(batch, |a: &Request, b: &Request| a.handle.same_keys(&b.handle));
                if groups.len() > 1 {
                    dispatch_metrics.record_keyed_splits((groups.len() - 1) as u64);
                }
                for g in groups {
                    let item = WorkItem { handle: g[0].handle.clone(), requests: g };
                    if work_txs[next % work_txs.len()].send(item).is_err() {
                        return;
                    }
                    next += 1;
                }
            }
        });
        let workers = work_rxs
            .into_iter()
            .map(|rx| {
                let plan = plan.clone();
                let metrics = metrics.clone();
                let inflight = inflight.clone();
                let killed = killed.clone();
                let backend = opts.backend.clone();
                let legacy = opts.legacy_exec;
                let fft_threads = opts.fft_threads;
                let sink = sink.clone();
                std::thread::spawn(move || match backend {
                    BackendKind::Native => worker_loop(
                        rx,
                        |h: &KeyHandle| {
                            Engine::new(NativePbsBackend::shared_with(
                                h.keys.clone(),
                                &EngineOptions { fft_threads },
                            ))
                        },
                        |e: &mut Engine<NativePbsBackend<'static>>, h: &KeyHandle| {
                            e.backend.set_keys(h.keys.clone())
                        },
                        &plan,
                        legacy,
                        &metrics,
                        &inflight,
                        &killed,
                        sink.as_ref(),
                    ),
                    BackendKind::NativeChaos { faults } => worker_loop(
                        rx,
                        move |h: &KeyHandle| {
                            Engine::new(FaultyBackend::new(
                                NativePbsBackend::shared_with(
                                    h.keys.clone(),
                                    &EngineOptions { fft_threads },
                                ),
                                faults.clone(),
                            ))
                        },
                        |e: &mut Engine<FaultyBackend<NativePbsBackend<'static>>>,
                         h: &KeyHandle| {
                            e.backend.inner_mut().set_keys(h.keys.clone())
                        },
                        &plan,
                        legacy,
                        &metrics,
                        &inflight,
                        &killed,
                        sink.as_ref(),
                    ),
                    #[cfg(feature = "xla")]
                    BackendKind::Xla { artifacts_dir } => worker_loop(
                        rx,
                        move |h: &KeyHandle| {
                            let be = crate::runtime::XlaPbsBackend::new(
                                &artifacts_dir,
                                &h.keys.params,
                                &h.keys.bsk,
                                &h.keys.ksk,
                            )
                            .expect("xla backend");
                            Engine::new(be)
                        },
                        |_e: &mut Engine<crate::runtime::XlaPbsBackend>, _h: &KeyHandle| {
                            panic!(
                                "the XLA backend bakes keys into device buffers and cannot \
                                 rebind per sub-batch; serve multi-tenant stores natively"
                            )
                        },
                        &plan,
                        legacy,
                        &metrics,
                        &inflight,
                        &killed,
                        sink.as_ref(),
                    ),
                    #[cfg(not(feature = "xla"))]
                    BackendKind::Xla { .. } => {
                        panic!("XLA backend requested but built without the `xla` feature")
                    }
                })
            })
            .collect();
        Self {
            intake: Some(intake_tx),
            metrics,
            store,
            dispatch: Some(dispatch),
            workers,
            inflight,
            plan,
            max_queue_depth: opts.max_queue_depth,
            fft_threads: opts.fft_threads,
            killed,
        }
    }

    /// The compiled plan the workers execute (for reporting and for
    /// costing the very same artifact in `arch::sim`).
    pub fn plan(&self) -> &CompiledPlan {
        &self.plan
    }

    /// The session key store requests resolve through.
    pub fn store(&self) -> &Arc<dyn KeyStore> {
        &self.store
    }

    /// Metrics plus the key store's cache counters — the full per-shard
    /// observability view (`self.metrics.snapshot()` alone reports the
    /// request-path counters with the key fields zeroed).
    pub fn snapshot(&self) -> MetricsSnapshot {
        let mut s = self.metrics.snapshot();
        let ks = self.store.stats();
        s.key_hits = ks.hits;
        s.key_misses = ks.misses;
        s.key_evictions = ks.evictions;
        s.key_regenerations = ks.regenerations;
        s.key_resident = ks.resident;
        s.key_pinned = ks.pinned;
        s.fft_threads = self.fft_threads;
        s.blocked_fft = crate::tfhe::fft::blocked_for_poly(self.plan.params.big_n);
        s
    }

    /// Submit one encrypted query for the default session (the
    /// single-tenant compat path — under [`StaticKeys`] every session
    /// resolves to the same keys).
    pub fn submit(&self, inputs: Vec<LweCiphertext>) -> Result<Ticket, SubmitError> {
        self.submit_for(SessionId::default(), inputs)
    }

    /// [`Self::submit`] with a per-request deadline: the returned
    /// ticket's `wait()` yields [`RequestError::RequestTimeout`] once
    /// `deadline` has elapsed without a response.
    pub fn submit_with_deadline(
        &self,
        inputs: Vec<LweCiphertext>,
        deadline: Duration,
    ) -> Result<Ticket, SubmitError> {
        self.submit_for_with_deadline(SessionId::default(), inputs, Some(deadline))
    }

    /// Submit one encrypted query for `session`; returns the [`Ticket`]
    /// the response will arrive on, [`SubmitError::Stopped`] after
    /// shutdown, or [`SubmitError::QueueFull`] when `max_queue_depth`
    /// requests are already outstanding. Key resolution happens here — a
    /// first-touch session on a seeded store pays its keygen at admission
    /// time, on the submitting thread.
    pub fn submit_for(
        &self,
        session: SessionId,
        inputs: Vec<LweCiphertext>,
    ) -> Result<Ticket, SubmitError> {
        self.submit_for_with_deadline(session, inputs, None)
    }

    /// [`Self::submit_for`] with an optional per-request deadline.
    pub fn submit_for_with_deadline(
        &self,
        session: SessionId,
        inputs: Vec<LweCiphertext>,
        deadline: Option<Duration>,
    ) -> Result<Ticket, SubmitError> {
        self.try_submit(session, inputs, deadline).map_err(|(e, _)| e)
    }

    /// Submission that hands the inputs back on failure, so the cluster
    /// can redirect the request to another shard without cloning
    /// ciphertexts up front. Mints the request's trace id here — the
    /// cluster path mints its own at cluster admission and goes through
    /// [`Self::try_submit_traced`] instead, so a redirected request keeps
    /// one id across shards.
    pub(crate) fn try_submit(
        &self,
        session: SessionId,
        inputs: Vec<LweCiphertext>,
        deadline: Option<Duration>,
    ) -> Result<Ticket, (SubmitError, Vec<LweCiphertext>)> {
        let trace = obs::next_trace_id();
        obs::trace::async_begin("request", trace);
        let out = self.try_submit_traced(session, inputs, deadline, trace);
        if out.is_err() && trace != 0 {
            // Never admitted: close the async span here (no ticket will),
            // with a terminal instant naming the shed.
            obs::trace::instant("rejected", trace);
            obs::trace::async_end("request", trace);
        }
        out
    }

    /// [`Self::try_submit`] under a caller-minted trace id (0 = untraced).
    pub(crate) fn try_submit_traced(
        &self,
        session: SessionId,
        inputs: Vec<LweCiphertext>,
        deadline: Option<Duration>,
        trace: u64,
    ) -> Result<Ticket, (SubmitError, Vec<LweCiphertext>)> {
        let Some(intake) = self.intake.as_ref() else {
            return Err((SubmitError::Stopped, inputs));
        };
        if !try_claim_slot(&self.inflight, self.max_queue_depth) {
            return Err((SubmitError::QueueFull, inputs));
        }
        let handle = match self.store.try_resolve(session) {
            Ok(h) => h,
            Err(_) => {
                self.inflight.fetch_sub(1, Ordering::SeqCst);
                return Err((SubmitError::ResolveFailed, inputs));
            }
        };
        let (tx, rx) = channel();
        let req = Request {
            session,
            handle,
            inputs,
            enqueued: Instant::now(),
            respond: tx,
            retries: 0,
            trace,
        };
        match intake.send(req) {
            Ok(()) => Ok(Ticket::new(
                rx,
                deadline.map(|d| Instant::now() + d),
                self.metrics.clone(),
                trace,
            )),
            Err(e) => {
                self.inflight.fetch_sub(1, Ordering::SeqCst);
                Err((SubmitError::Stopped, e.0.inputs))
            }
        }
    }

    /// Re-enqueue a request that failed on another shard, keeping its
    /// original response channel so the client's ticket resolves from
    /// wherever the retry lands. Bypasses this shard's `max_queue_depth`
    /// (the request already holds cluster admission); returns the
    /// response sender on failure so the supervisor can fail the request
    /// terminally.
    pub(crate) fn resubmit(
        &self,
        session: SessionId,
        inputs: Vec<LweCiphertext>,
        respond: Sender<Response>,
        retries: u32,
        trace: u64,
    ) -> Result<(), Sender<Response>> {
        let Some(intake) = self.intake.as_ref() else {
            return Err(respond);
        };
        let handle = match self.store.try_resolve(session) {
            Ok(h) => h,
            Err(_) => return Err(respond),
        };
        self.inflight.fetch_add(1, Ordering::SeqCst);
        let req =
            Request { session, handle, inputs, enqueued: Instant::now(), respond, retries, trace };
        match intake.send(req) {
            Ok(()) => Ok(()),
            Err(e) => {
                self.inflight.fetch_sub(1, Ordering::SeqCst);
                Err(e.0.respond)
            }
        }
    }

    /// Graceful shutdown: close intake, drain workers. Subsequent
    /// [`Self::submit`] calls return [`SubmitError::Stopped`].
    pub fn shutdown(&mut self) {
        drop(self.intake.take());
        if let Some(d) = self.dispatch.take() {
            let _ = d.join();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }

    /// Hard stop: like a shard dying mid-flight. Queued and in-flight
    /// requests are NOT executed — each waiter's ticket resolves to
    /// [`RequestError::ShardLost`] (a typed error, never a hang) as the
    /// workers drain the remaining queue without running it.
    pub fn kill(&mut self) {
        self.killed.store(true, Ordering::SeqCst);
        self.shutdown();
    }
}

/// Best-effort human-readable reason from a caught panic payload.
fn panic_reason(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "worker panicked".to_string()
    }
}

/// Execute keyed sub-batches as they arrive. The engine is built lazily
/// from the first sub-batch's key handle (`mk_engine`) and rebound
/// (`rebind`) whenever a sub-batch carries different key material — the
/// FFT plan, scratch, and accumulator cache persist across rebinds; only
/// the key pointer changes.
///
/// Execution runs under `catch_unwind`: a panicking backend fails only
/// this sub-batch (typed [`RequestError::ExecFailed`] per request, or a
/// forward to `sink` when supervised), the poisoned engine is dropped —
/// discarding its partial `ExecStats`, so measured counters stay
/// success-only — and the next sub-batch rebuilds it via `mk_engine`:
/// an in-place worker respawn. Batch/exec counters are recorded only
/// *after* a successful execution (but before the responses are sent, so
/// a snapshot taken right after the last response already sees them).
#[allow(clippy::too_many_arguments)]
fn worker_loop<B, MkE, Rb>(
    rx: Receiver<WorkItem>,
    mk_engine: MkE,
    mut rebind: Rb,
    plan: &CompiledPlan,
    legacy: bool,
    metrics: &Metrics,
    inflight: &AtomicUsize,
    killed: &AtomicBool,
    sink: Option<&FailureSink>,
) where
    B: PbsBackend,
    MkE: Fn(&KeyHandle) -> Engine<B>,
    Rb: FnMut(&mut Engine<B>, &KeyHandle),
{
    let mut engine: Option<Engine<B>> = None;
    let mut bound: Option<KeyHandle> = None;
    while let Ok(WorkItem { handle, requests }) = rx.recv() {
        if killed.load(Ordering::SeqCst) {
            // Hard-killed shard: drain without executing; every waiter
            // gets a typed error instead of a hung channel.
            for r in requests {
                inflight.fetch_sub(1, Ordering::SeqCst);
                let _ = r.respond.send(Err(RequestError::ShardLost));
            }
            continue;
        }
        match (engine.as_mut(), bound.as_ref()) {
            (Some(_), Some(b)) if b.same_keys(&handle) => {}
            (Some(e), _) => rebind(e, &handle),
            (None, _) => engine = Some(mk_engine(&handle)),
        }
        bound = Some(handle);

        let size = requests.len();
        let pbs = plan.graph.pbs_count() * size;
        // Inputs are moved out of the requests, not cloned; they are
        // still owned here after a failure, so retries re-use them.
        let (metas, inputs): (Vec<(SessionId, Instant, Sender<Response>, u32, u64)>, Vec<_>) =
            requests
                .into_iter()
                .map(|r| ((r.session, r.enqueued, r.respond, r.retries, r.trace), r.inputs))
                .unzip();
        let queue_ms: Vec<f64> =
            metas.iter().map(|(_, t, _, _, _)| t.elapsed().as_secs_f64() * 1e3).collect();
        let eng = engine.as_mut().expect("engine bound");
        // Default: walk the compiled schedule — shared key switches
        // computed once per batch, accumulator-sharing rotations fused
        // across nodes x requests into single BSK sweeps.
        let exec_span = obs::trace::start();
        let result = catch_unwind(AssertUnwindSafe(|| {
            if legacy {
                eng.run_batch(&plan.program, &inputs)
            } else {
                eng.run_plan_batch(plan, &inputs)
            }
        }));
        obs::trace::span("exec_batch", 0, exec_span);
        match result {
            Ok(outs) => {
                metrics.record_batch(size, pbs);
                // ExecStats drain per keyed sub-batch: KS/PBS/traffic
                // counters are attributed at the same granularity
                // execution actually ran.
                let st = eng.take_exec_stats();
                metrics.record_exec(st.ks_ops, st.bsk_bytes_streamed);
                if obs::enabled() {
                    // Stage timings and per-schedule-batch profiles drain
                    // with the same success-only semantics as the
                    // counters above (a failed batch drops its engine —
                    // and with it any partial timings — below).
                    metrics.record_stage_times(&eng.take_stage_times());
                    metrics.record_batch_profiles(&eng.take_batch_profiles());
                }
                for (((session, enqueued, respond, _, _), out), q_ms) in
                    metas.into_iter().zip(outs).zip(queue_ms)
                {
                    let latency_ms = enqueued.elapsed().as_secs_f64() * 1e3;
                    metrics.record_request(session, q_ms, latency_ms);
                    inflight.fetch_sub(1, Ordering::SeqCst);
                    let _ = respond.send(Ok(out)); // client may have gone away
                }
            }
            Err(payload) => {
                let reason = panic_reason(payload.as_ref());
                // The engine's internal state (scratch, partial stats) is
                // suspect after an unwound execution: drop and rebuild
                // from the next sub-batch's handle. Discard this thread's
                // FFT timing samples too, so the failed batch's partial
                // work never leaks into a later successful drain.
                engine = None;
                bound = None;
                let _ = obs::take_thread_fft();
                metrics.record_exec_failure(size as u64);
                metrics.record_worker_respawn();
                obs::trace::instant("worker_respawn", 0);
                for ((session, _, respond, retries, trace), input) in metas.into_iter().zip(inputs)
                {
                    obs::trace::instant("exec_failed", trace);
                    inflight.fetch_sub(1, Ordering::SeqCst);
                    match sink {
                        Some(s) => {
                            let failed = FailedRequest {
                                shard: s.shard,
                                generation: s.generation,
                                session,
                                inputs: input,
                                respond,
                                retries,
                                reason: reason.clone(),
                                trace,
                            };
                            if let Err(e) = s.tx.send(failed) {
                                // Supervisor gone: fail terminally.
                                let _ = e.0.respond.send(Err(RequestError::ExecFailed {
                                    reason: reason.clone(),
                                }));
                            }
                        }
                        None => {
                            let _ = respond
                                .send(Err(RequestError::ExecFailed { reason: reason.clone() }));
                        }
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::builder::ProgramBuilder;
    use crate::ir::interp;
    use crate::params::TEST1;
    use crate::tenant::{client_secret, SeededTenantStore};
    use crate::tfhe::pbs::{decrypt_message, encrypt_message};
    use crate::tfhe::SecretKeys;
    use crate::util::rng::Rng;

    fn small_program() -> Program {
        let mut b = ProgramBuilder::new("serve", 3);
        let x = b.input();
        let y = b.input();
        let s = b.add(x, y);
        let r = b.lut_fn(s, |m| (m * 2 + 1) % 16);
        b.output(r);
        b.finish()
    }

    #[test]
    fn serves_concurrent_requests_correctly() {
        let mut rng = Rng::new(31);
        let sk = SecretKeys::generate(&TEST1, &mut rng);
        let keys = Arc::new(ServerKeys::generate(&sk, &mut rng));
        let keys2 = keys.clone();
        let prog = small_program();
        let mut coord = Coordinator::start(
            prog.clone(),
            keys,
            CoordinatorOptions { workers: 3, batch_capacity: 4, ..Default::default() },
        );
        let queries: Vec<(u64, u64)> = (0..12).map(|i| (i % 6, (i * 3) % 6)).collect();
        let mut pending = Vec::new();
        for &(x, y) in &queries {
            let inputs =
                vec![encrypt_message(x, &sk, &mut rng), encrypt_message(y, &sk, &mut rng)];
            pending.push(coord.submit(inputs).expect("submit"));
        }
        for (rx, &(x, y)) in pending.iter().zip(&queries) {
            let outs = rx.recv().expect("response");
            let exp = interp::eval(&prog, &[x, y]);
            assert_eq!(decrypt_message(&outs[0], &sk), exp[0], "query ({x},{y})");
        }
        let snap = coord.metrics.snapshot();
        assert_eq!(snap.requests, 12);
        assert!(snap.batches >= 3, "round-robined to several batches");
        assert_eq!(coord.inflight.load(Ordering::SeqCst), 0);
        // One static key set: the keyed batcher never split a batch.
        assert_eq!(snap.keyed_batch_splits, 0);
        assert_eq!(snap.session_requests.get(&0), Some(&12), "compat path = one session");
        // Plan-driven accounting: one KS per request on this program.
        assert_eq!(snap.ks_executed, 12 * coord.plan().ks_dedup.after as u64);
        // Key-reuse accounting: fused sweeps stream at most one full BSK
        // per PBS (exactly one when a batch degenerates to size 1).
        assert!(snap.bsk_bytes_streamed > 0);
        let full = keys2.bsk.bytes() as f64;
        assert!(
            snap.bsk_bytes_per_pbs <= full + 1.0,
            "amortized {} vs full stream {}",
            snap.bsk_bytes_per_pbs,
            full
        );
        coord.shutdown();
    }

    #[test]
    fn plan_path_dedups_fanout_keyswitches_in_serving() {
        // N LUTs over one value: the plan path performs exactly 1 KS per
        // request where the legacy path performed N, and the measured
        // counts equal what `arch::sim` costs for the same plan.
        let mut rng = Rng::new(33);
        let sk = SecretKeys::generate(&TEST1, &mut rng);
        let keys = Arc::new(ServerKeys::generate(&sk, &mut rng));
        let n_luts = 3usize;
        let mut b = ProgramBuilder::new("fanout-serve", 3);
        let x = b.input();
        for k in 0..n_luts as u64 {
            let y = b.lut_fn(x, move |m| (m + k) % 16);
            b.output(y);
        }
        let prog = b.finish();

        let run = |legacy: bool, rng: &mut Rng| -> (u64, u64) {
            let mut coord = Coordinator::start(
                prog.clone(),
                keys.clone(),
                CoordinatorOptions { workers: 1, legacy_exec: legacy, ..Default::default() },
            );
            let requests = 4usize;
            let mut pending = Vec::new();
            for i in 0..requests {
                let m = (i % 6) as u64;
                pending.push((m, coord.submit(vec![encrypt_message(m, &sk, rng)]).unwrap()));
            }
            for (m, rx) in &pending {
                let outs = rx.recv().expect("response");
                let exp = interp::eval(&prog, &[*m]);
                let got: Vec<u64> = outs.iter().map(|c| decrypt_message(c, &sk)).collect();
                assert_eq!(got, exp, "m={m} legacy={legacy}");
            }
            let snap = coord.metrics.snapshot();
            coord.shutdown();
            (snap.ks_executed, snap.pbs_executed as u64)
        };
        let (plan_ks, plan_pbs) = run(false, &mut rng);
        let (legacy_ks, legacy_pbs) = run(true, &mut rng);
        assert_eq!(plan_ks, 4, "1 KS per request on the plan path");
        assert_eq!(legacy_ks, (4 * n_luts) as u64, "N KS per request legacy");
        assert_eq!(plan_pbs, legacy_pbs, "identical PBS work");

        // The very same plan costed by the arch model agrees per request.
        let plan = crate::compiler::compile(&prog, &TEST1, 48usize);
        let cfg = crate::arch::TaurusConfig::default();
        let r = crate::arch::simulate(&plan, &cfg);
        assert_eq!(r.ks_count as u64, plan_ks / 4);
        assert_eq!(r.pbs_count as u64, plan_pbs / 4);
    }

    #[test]
    fn shutdown_is_clean_with_no_requests() {
        let mut rng = Rng::new(32);
        let sk = SecretKeys::generate(&TEST1, &mut rng);
        let keys = Arc::new(ServerKeys::generate(&sk, &mut rng));
        let mut coord = Coordinator::start(small_program(), keys, Default::default());
        coord.shutdown();
    }

    #[test]
    fn submit_after_shutdown_returns_err_not_panic() {
        let mut rng = Rng::new(34);
        let sk = SecretKeys::generate(&TEST1, &mut rng);
        let keys = Arc::new(ServerKeys::generate(&sk, &mut rng));
        let mut coord = Coordinator::start(small_program(), keys, Default::default());
        coord.shutdown();
        let inputs = vec![
            encrypt_message(1, &sk, &mut rng),
            encrypt_message(2, &sk, &mut rng),
        ];
        assert_eq!(coord.submit(inputs).unwrap_err(), SubmitError::Stopped);
        assert_eq!(coord.inflight.load(Ordering::SeqCst), 0, "no leaked inflight");
    }

    #[test]
    fn bounded_queue_sheds_load_then_recovers() {
        let mut rng = Rng::new(35);
        let sk = SecretKeys::generate(&TEST1, &mut rng);
        let keys = Arc::new(ServerKeys::generate(&sk, &mut rng));
        // One worker, a batcher that holds requests for a long window, and
        // a depth-2 bound: the 3rd submission must be shed while the first
        // two are still queued.
        let mut coord = Coordinator::start(
            small_program(),
            keys,
            CoordinatorOptions {
                workers: 1,
                batch_capacity: 64,
                max_batch_wait: Duration::from_millis(300),
                max_queue_depth: Some(2),
                ..Default::default()
            },
        );
        let enc = |rng: &mut Rng| {
            vec![encrypt_message(1, &sk, rng), encrypt_message(2, &sk, rng)]
        };
        let a = coord.submit(enc(&mut rng)).expect("first admitted");
        let b = coord.submit(enc(&mut rng)).expect("second admitted");
        assert_eq!(
            coord.submit(enc(&mut rng)).unwrap_err(),
            SubmitError::QueueFull,
            "third submission sheds load at depth 2"
        );
        // Once the held batch executes, the slots free up and intake
        // accepts again.
        let _ = a.recv().expect("first response");
        let _ = b.recv().expect("second response");
        let c = coord.submit(enc(&mut rng)).expect("admitted after drain");
        let _ = c.recv().expect("third response");
        assert_eq!(coord.inflight.load(Ordering::SeqCst), 0);
        let snap = coord.metrics.snapshot();
        assert_eq!(snap.requests, 3, "shed request was never executed");
        coord.shutdown();
    }

    #[test]
    fn keyed_grouping_splits_mixed_tenant_batches_deterministically() {
        // Two tenants interleaved into ONE collected batch (capacity 4,
        // generous wait): the dispatch must split it into exactly two
        // keyed sub-batches, each executed under its own tenant's keys.
        let master = 0x5E55;
        let store = Arc::new(SeededTenantStore::new(&TEST1, master, 4));
        let prog = small_program();
        let mut coord = Coordinator::start_with_store(
            prog.clone(),
            store.clone(),
            CoordinatorOptions {
                workers: 1,
                batch_capacity: 4,
                max_batch_wait: Duration::from_millis(400),
                ..Default::default()
            },
        );
        let mut rng = Rng::new(36);
        let sks: Vec<_> =
            (0..2).map(|t| client_secret(&TEST1, master, SessionId(t))).collect();
        // Pre-warm both tenants so keygen latency cannot straddle the
        // batcher window — the 4 submissions below all land inside it.
        store.resolve(SessionId(0));
        store.resolve(SessionId(1));
        // t0, t1, t0, t1 — one batcher window.
        let mut pending = Vec::new();
        for i in 0..4u64 {
            let t = (i % 2) as usize;
            let (x, y) = (i % 6, (i * 3) % 6);
            let inputs = vec![
                encrypt_message(x, &sks[t], &mut rng),
                encrypt_message(y, &sks[t], &mut rng),
            ];
            pending.push((t, x, y, coord.submit_for(SessionId(t as u64), inputs).unwrap()));
        }
        for (t, x, y, rx) in &pending {
            let outs = rx.recv().expect("response");
            let exp = interp::eval(&prog, &[*x, *y]);
            assert_eq!(
                decrypt_message(&outs[0], &sks[*t]),
                exp[0],
                "tenant {t} query ({x},{y}) under its own key"
            );
        }
        let snap = coord.snapshot();
        assert_eq!(snap.requests, 4);
        assert_eq!(snap.batches, 2, "one collected batch split into two keyed sub-batches");
        assert_eq!(snap.keyed_batch_splits, 1);
        assert_eq!(snap.session_requests.get(&0), Some(&2));
        assert_eq!(snap.session_requests.get(&1), Some(&2));
        // 2 pre-warm misses + 4 submit-time hits, nothing evicted.
        assert_eq!((snap.key_misses, snap.key_hits, snap.key_evictions), (2, 4, 0));
        assert_eq!(snap.key_resident, 2);
        coord.shutdown();
    }
}
