//! Dynamic batcher: groups queued requests up to a capacity or a max-wait
//! deadline — the serving-side realization of the paper's batch-size
//! lever (Observation 7: accelerator parallelism is harvested by batching
//! real queries).

use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::time::{Duration, Instant};

/// Pull up to `capacity` items from `rx`, waiting at most `max_wait` after
/// the first item arrives. Returns an empty vec when the channel closed.
/// A `capacity` of 0 returns empty immediately without touching the
/// channel (callers that treat an empty batch as "intake closed", like the
/// coordinator's dispatch loop, must reject capacity 0 up front).
pub fn collect_batch<T>(rx: &Receiver<T>, capacity: usize, max_wait: Duration) -> Vec<T> {
    let mut out = Vec::new();
    if capacity == 0 {
        return out;
    }
    // Block for the first element (or closure).
    match rx.recv() {
        Ok(item) => out.push(item),
        Err(_) => return out,
    }
    let deadline = Instant::now() + max_wait;
    while out.len() < capacity {
        let now = Instant::now();
        if now >= deadline {
            break;
        }
        match rx.recv_timeout(deadline - now) {
            Ok(item) => out.push(item),
            Err(RecvTimeoutError::Timeout) => break,
            Err(RecvTimeoutError::Disconnected) => break,
        }
    }
    out
}

/// A simple marker struct so callers can name the policy in configs.
#[derive(Debug, Clone)]
pub struct DynamicBatcher {
    pub capacity: usize,
    pub max_wait: Duration,
}

impl DynamicBatcher {
    pub fn new(capacity: usize, max_wait: Duration) -> Self {
        Self { capacity, max_wait }
    }

    pub fn collect<T>(&self, rx: &Receiver<T>) -> Vec<T> {
        collect_batch(rx, self.capacity, self.max_wait)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;

    #[test]
    fn collects_up_to_capacity() {
        let (tx, rx) = channel();
        for i in 0..10 {
            tx.send(i).unwrap();
        }
        let b = DynamicBatcher::new(4, Duration::from_millis(5));
        assert_eq!(b.collect(&rx), vec![0, 1, 2, 3]);
        assert_eq!(b.collect(&rx), vec![4, 5, 6, 7]);
    }

    #[test]
    fn times_out_with_partial_batch() {
        let (tx, rx) = channel();
        tx.send(1).unwrap();
        let b = DynamicBatcher::new(8, Duration::from_millis(10));
        let batch = b.collect(&rx);
        assert_eq!(batch, vec![1]);
    }

    #[test]
    fn closed_channel_returns_empty() {
        let (tx, rx) = channel::<u32>();
        drop(tx);
        let b = DynamicBatcher::new(4, Duration::from_millis(1));
        assert!(b.collect(&rx).is_empty());
    }

    #[test]
    fn zero_capacity_returns_empty_without_blocking_or_consuming() {
        // Regression: capacity 0 used to block on the first recv and hand
        // back a 1-element "batch" anyway.
        let (tx, rx) = channel();
        tx.send(7).unwrap();
        let b = DynamicBatcher::new(0, Duration::from_secs(3600));
        let t0 = std::time::Instant::now();
        assert!(b.collect(&rx).is_empty());
        assert!(t0.elapsed() < Duration::from_secs(1), "must not block");
        // The queued item was not swallowed.
        assert_eq!(rx.try_recv(), Ok(7));
    }

    #[test]
    fn waits_for_late_arrivals_within_deadline() {
        let (tx, rx) = channel();
        let t = std::thread::spawn(move || {
            tx.send(1).unwrap();
            std::thread::sleep(Duration::from_millis(5));
            tx.send(2).unwrap();
        });
        let b = DynamicBatcher::new(2, Duration::from_millis(200));
        let batch = b.collect(&rx);
        t.join().unwrap();
        assert_eq!(batch, vec![1, 2]);
    }
}
