//! Dynamic batcher: groups queued requests up to a capacity or a max-wait
//! deadline — the serving-side realization of the paper's batch-size
//! lever (Observation 7: accelerator parallelism is harvested by batching
//! real queries).
//!
//! Failure semantics: batching is fail-closed from the waiter's point of
//! view. A closed intake drains cleanly ([`collect_batch`] returns
//! partial batches, then empty), so on shutdown every already-queued
//! request still reaches a worker — which answers it, or fails it with a
//! typed error when the coordinator was hard-killed. The batch boundary
//! is also the failure boundary upstream: a panicking backend fails
//! exactly one keyed sub-batch produced here, never the batcher or
//! dispatch thread.

use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::time::{Duration, Instant};

/// Pull up to `capacity` items from `rx`, waiting at most `max_wait` after
/// the first item arrives. Returns an empty vec when the channel closed.
/// A `capacity` of 0 returns empty immediately without touching the
/// channel (callers that treat an empty batch as "intake closed", like the
/// coordinator's dispatch loop, must reject capacity 0 up front).
pub fn collect_batch<T>(rx: &Receiver<T>, capacity: usize, max_wait: Duration) -> Vec<T> {
    let mut out = Vec::new();
    if capacity == 0 {
        return out;
    }
    // Block for the first element (or closure).
    match rx.recv() {
        Ok(item) => out.push(item),
        Err(_) => return out,
    }
    let deadline = Instant::now() + max_wait;
    while out.len() < capacity {
        let now = Instant::now();
        if now >= deadline {
            break;
        }
        match rx.recv_timeout(deadline - now) {
            Ok(item) => out.push(item),
            Err(RecvTimeoutError::Timeout) => break,
            Err(RecvTimeoutError::Disconnected) => break,
        }
    }
    out
}

/// Partition one collected batch into execution sub-batches of items that
/// share a key (per `same`), preserving arrival order within each group
/// and first-appearance order across groups. This is the multi-tenant
/// grouping step: `Engine::run_plan_batch` executes one batch under ONE
/// key set, so a collected batch spanning several tenants must split —
/// each extra group is one `keyed_batch_splits` tick in the metrics. With
/// a single key (the `StaticKeys` compat path) the batch passes through
/// as exactly one group, bit-identical to the pre-session dispatch.
pub fn group_batch<T>(items: Vec<T>, same: impl Fn(&T, &T) -> bool) -> Vec<Vec<T>> {
    let mut groups: Vec<Vec<T>> = Vec::new();
    for item in items {
        match groups.iter_mut().find(|g| same(&g[0], &item)) {
            Some(g) => g.push(item),
            None => groups.push(vec![item]),
        }
    }
    groups
}

/// A simple marker struct so callers can name the policy in configs.
#[derive(Debug, Clone)]
pub struct DynamicBatcher {
    pub capacity: usize,
    pub max_wait: Duration,
}

impl DynamicBatcher {
    pub fn new(capacity: usize, max_wait: Duration) -> Self {
        Self { capacity, max_wait }
    }

    pub fn collect<T>(&self, rx: &Receiver<T>) -> Vec<T> {
        collect_batch(rx, self.capacity, self.max_wait)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;

    #[test]
    fn collects_up_to_capacity() {
        let (tx, rx) = channel();
        for i in 0..10 {
            tx.send(i).unwrap();
        }
        let b = DynamicBatcher::new(4, Duration::from_millis(5));
        assert_eq!(b.collect(&rx), vec![0, 1, 2, 3]);
        assert_eq!(b.collect(&rx), vec![4, 5, 6, 7]);
    }

    #[test]
    fn times_out_with_partial_batch() {
        let (tx, rx) = channel();
        tx.send(1).unwrap();
        let b = DynamicBatcher::new(8, Duration::from_millis(10));
        let batch = b.collect(&rx);
        assert_eq!(batch, vec![1]);
    }

    #[test]
    fn closed_channel_returns_empty() {
        let (tx, rx) = channel::<u32>();
        drop(tx);
        let b = DynamicBatcher::new(4, Duration::from_millis(1));
        assert!(b.collect(&rx).is_empty());
    }

    #[test]
    fn zero_capacity_returns_empty_without_blocking_or_consuming() {
        // Regression: capacity 0 used to block on the first recv and hand
        // back a 1-element "batch" anyway.
        let (tx, rx) = channel();
        tx.send(7).unwrap();
        let b = DynamicBatcher::new(0, Duration::from_secs(3600));
        let t0 = std::time::Instant::now();
        assert!(b.collect(&rx).is_empty());
        assert!(t0.elapsed() < Duration::from_secs(1), "must not block");
        // The queued item was not swallowed.
        assert_eq!(rx.try_recv(), Ok(7));
    }

    #[test]
    fn capacity_one_fast_path_returns_without_waiting_the_deadline() {
        // The keyed-grouping change sits on collect's timing semantics:
        // a full batch must never sit out the max_wait window.
        let (tx, rx) = channel();
        tx.send(42).unwrap();
        let b = DynamicBatcher::new(1, Duration::from_secs(3600));
        let t0 = std::time::Instant::now();
        assert_eq!(b.collect(&rx), vec![42]);
        assert!(t0.elapsed() < Duration::from_secs(1), "capacity-1 must not wait");
    }

    #[test]
    fn burst_at_capacity_returns_immediately() {
        let (tx, rx) = channel();
        for i in 0..4 {
            tx.send(i).unwrap();
        }
        let b = DynamicBatcher::new(4, Duration::from_secs(3600));
        let t0 = std::time::Instant::now();
        assert_eq!(b.collect(&rx), vec![0, 1, 2, 3]);
        assert!(t0.elapsed() < Duration::from_secs(1), "full burst must not wait");
    }

    #[test]
    fn partial_batch_waits_out_the_full_deadline() {
        // One item then silence: collect must hold the batch open for the
        // whole max_wait window (the latency the batcher trades for
        // batching opportunity) before returning the partial batch.
        let (tx, rx) = channel();
        tx.send(1).unwrap();
        let wait = Duration::from_millis(60);
        let b = DynamicBatcher::new(8, wait);
        let t0 = std::time::Instant::now();
        assert_eq!(b.collect(&rx), vec![1]);
        let elapsed = t0.elapsed();
        assert!(elapsed >= wait - Duration::from_millis(5), "returned after {elapsed:?}");
        assert!(elapsed < Duration::from_secs(5), "but not unboundedly late");
    }

    #[test]
    fn empty_at_close_while_blocked_on_first_item() {
        // No item ever arrives; the channel closes after a delay. collect
        // blocks on the first recv (there is no deadline before the first
        // item) and returns empty at closure — the dispatch loop's
        // shutdown signal.
        let (tx, rx) = channel::<u32>();
        let t = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(20));
            drop(tx);
        });
        let b = DynamicBatcher::new(4, Duration::from_millis(1));
        assert!(b.collect(&rx).is_empty());
        t.join().unwrap();
    }

    #[test]
    fn group_batch_splits_by_key_preserving_order() {
        let items = vec![(1, 'a'), (2, 'b'), (1, 'c'), (3, 'd'), (2, 'e'), (1, 'f')];
        let groups = group_batch(items, |x, y| x.0 == y.0);
        assert_eq!(
            groups,
            vec![
                vec![(1, 'a'), (1, 'c'), (1, 'f')],
                vec![(2, 'b'), (2, 'e')],
                vec![(3, 'd')],
            ],
            "arrival order within groups, first-appearance order across"
        );
        // Single key: one pass-through group (the StaticKeys path).
        let one = group_batch(vec![7, 7, 7], |a, b| a == b);
        assert_eq!(one, vec![vec![7, 7, 7]]);
        assert!(group_batch(Vec::<u8>::new(), |a: &u8, b: &u8| a == b).is_empty());
    }

    #[test]
    fn waits_for_late_arrivals_within_deadline() {
        let (tx, rx) = channel();
        let t = std::thread::spawn(move || {
            tx.send(1).unwrap();
            std::thread::sleep(Duration::from_millis(5));
            tx.send(2).unwrap();
        });
        let b = DynamicBatcher::new(2, Duration::from_millis(200));
        let batch = b.collect(&rx);
        t.join().unwrap();
        assert_eq!(batch, vec![1, 2]);
    }
}
