//! Serving coordinator — the L3 runtime frontend (vLLM-router-style):
//! clients submit encrypted inputs *for a session* of a compiled FHE
//! program; a `tenant::KeyStore` resolves each session's server keys at
//! admission, a dynamic batcher groups requests (the paper's batch-size
//! lever, Fig. 15 / Observation 7) and splits each collected batch by key
//! handle so every execution batch runs under one key set, a worker pool
//! executes them on the native or XLA PBS backend (the native backend
//! rebinds tenant keys between sub-batches), and metrics report
//! latency/throughput plus per-tenant counts and key-cache counters.
//!
//! Python never appears here: the XLA backend executes AOT artifacts via
//! PJRT (see `runtime`).
//!
//! One coordinator is one engine shard; `crate::cluster` replicates N of
//! them behind a placement router with a shared admission queue and
//! shard-local key stores.
//!
//! Failure model: submissions return a [`Ticket`] that always
//! terminates — with output ciphertexts, a typed [`RequestError`]
//! (batch panic, hard shard loss, resolve failure), or
//! [`RequestError::RequestTimeout`] when a deadline was attached — and
//! workers survive backend panics by catching at the batch boundary and
//! respawning their engine (see `server`).

pub mod batcher;
pub mod metrics;
pub mod server;

pub use batcher::DynamicBatcher;
pub use metrics::{Metrics, MetricsSnapshot};
pub use server::{
    BackendKind, Coordinator, CoordinatorOptions, RequestError, SubmitError, Ticket,
};
