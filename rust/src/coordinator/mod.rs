//! Serving coordinator — the L3 runtime frontend (vLLM-router-style):
//! clients submit encrypted inputs for a compiled FHE program; a dynamic
//! batcher groups them (the paper's batch-size lever, Fig. 15 /
//! Observation 7), a worker pool executes them on the native or XLA PBS
//! backend, and metrics report latency/throughput.
//!
//! Python never appears here: the XLA backend executes AOT artifacts via
//! PJRT (see `runtime`).
//!
//! One coordinator is one engine shard; `crate::cluster` replicates N of
//! them behind a placement router with a shared admission queue.

pub mod batcher;
pub mod metrics;
pub mod server;

pub use batcher::DynamicBatcher;
pub use metrics::{Metrics, MetricsSnapshot};
pub use server::{BackendKind, Coordinator, CoordinatorOptions, SubmitError};
