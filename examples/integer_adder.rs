//! Fig. 5 reproduction: add two 6-bit integers under three TFHE
//! representations and measure real wall-clock on the native library.
//!
//!     cargo run --release --example integer_adder
//!
//! The Boolean ripple-carry adder pays one bootstrap per gate (27 PBS);
//! the radix-split adder needs one dependent PBS level (2 PBS); the wide
//! representation adds with zero bootstraps (paper: 253 ms / 47 ms /
//! 0.008 ms on EPYC 7R13 at the paper's parameter sets).

use std::time::Instant;

use taurus::compiler::{Engine, NativePbsBackend};
use taurus::ir::interp;
use taurus::params::TEST1;
use taurus::tfhe::pbs::{decrypt_message, encrypt_message};
use taurus::tfhe::{SecretKeys, ServerKeys};
use taurus::util::rng::Rng;
use taurus::workloads::adder;

fn main() {
    let mut rng = Rng::new(11);
    println!("keygen at TEST1 (N=512, n=128; test-scale, not 128-bit secure)...");
    let sk = SecretKeys::generate(&TEST1, &mut rng);
    let keys = ServerKeys::generate(&sk, &mut rng);

    let (x, y) = (11u64, 22u64);
    println!("computing {x} + {y} under three representations:\n");

    // --- Boolean ripple-carry: 12 one-bit ciphertexts, 27 gate PBS.
    let prog = adder::boolean_ripple_carry_at(6, TEST1.width);
    let mut inputs = Vec::new();
    for i in 0..6 {
        inputs.push((x >> i) & 1);
    }
    for i in 0..6 {
        inputs.push((y >> i) & 1);
    }
    let cts: Vec<_> = inputs.iter().map(|&m| encrypt_message(m, &sk, &mut rng)).collect();
    let mut eng = Engine::new(NativePbsBackend::new(&keys));
    let t0 = Instant::now();
    let outs = eng.run(&prog, &cts);
    let t_bool = t0.elapsed().as_secs_f64() * 1e3;
    let bits: Vec<u64> = outs.iter().map(|c| decrypt_message(c, &sk)).collect();
    let got: u64 = bits.iter().enumerate().map(|(i, &b)| (b & 1) << i).sum();
    assert_eq!(got, x + y);
    println!("Boolean ripple-carry : {:>8.2} ms   ({} PBS) -> {got}", t_bool, prog.pbs_count());

    // --- Radix split (two 3-bit digits in TEST1's 3-bit space... digits
    // of width/2 bits; carries via LUT): 2 PBS, 1 level.
    let prog = adder::radix_split_adder(TEST1.width + 3); // 6-bit digits space
    // Run at reduced digit width on TEST1 for wall-clock comparability:
    let prog_small = adder::radix_split_adder(TEST1.width.max(2));
    let d = 1u64 << (prog_small.width / 2);
    let (xs, ys) = (x % (d * d), y % (d * d));
    let inputs = [xs % d, xs / d, ys % d, ys / d];
    let cts: Vec<_> = inputs.iter().map(|&m| encrypt_message(m, &sk, &mut rng)).collect();
    let t0 = Instant::now();
    let outs = eng.run(&prog_small, &cts);
    let t_radix = t0.elapsed().as_secs_f64() * 1e3;
    let exp = interp::eval(&prog_small, &inputs);
    let got: Vec<u64> = outs.iter().map(|c| decrypt_message(c, &sk)).collect();
    assert_eq!(got, exp);
    println!(
        "Radix split          : {:>8.2} ms   ({} PBS) -> digits {:?} (full 6-bit variant: {} PBS)",
        t_radix,
        prog_small.pbs_count(),
        got,
        prog.pbs_count(),
    );

    // --- Wide representation: single homomorphic add, zero PBS.
    let prog = adder::wide_adder(TEST1.width);
    let (xw, yw) = (x % 8, y % 8);
    let cts = vec![encrypt_message(xw, &sk, &mut rng), encrypt_message(yw, &sk, &mut rng)];
    let t0 = Instant::now();
    let outs = eng.run(&prog, &cts);
    let t_wide = t0.elapsed().as_secs_f64() * 1e3;
    let got = decrypt_message(&outs[0], &sk);
    assert_eq!(got, (xw + yw) % 16);
    println!("Wide (single add)    : {t_wide:>8.4} ms   (0 PBS) -> {got}");

    println!(
        "\nshape check: Boolean >> radix >> wide  ({:.1}x and {:.0}x)",
        t_bool / t_radix,
        t_radix / t_wide.max(1e-6)
    );
    println!("paper (EPYC 7R13, paper params): 253 ms / 47 ms / 0.008 ms");
}
