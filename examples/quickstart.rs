//! Quickstart: the public API in ~40 lines.
//!
//! Client side: generate keys, encrypt 4-bit-space integers.
//! Server side: homomorphic linear ops + one programmable bootstrap.
//!
//!     cargo run --release --example quickstart

use taurus::params::TEST1;
use taurus::tfhe::pbs::{decrypt_message, encrypt_message};
use taurus::tfhe::{make_lut_poly, PbsContext, SecretKeys, ServerKeys};
use taurus::util::rng::Rng;

fn main() {
    let mut rng = Rng::new(7);

    // --- client: keypair. `sk` never leaves the client; `keys` (BSK+KSK)
    // go to the server (paper Fig. 1).
    let sk = SecretKeys::generate(&TEST1, &mut rng);
    let server_keys = ServerKeys::generate(&sk, &mut rng);

    // --- client: encrypt x = 3, y = 2.
    let ct_x = encrypt_message(3, &sk, &mut rng);
    let ct_y = encrypt_message(2, &sk, &mut rng);

    // --- server: compute relu(x + y - 4) * 2 without the secret key.
    let mut ctx = PbsContext::new(&TEST1);
    let mut sum = ct_x.clone();
    sum.add_assign(&ct_y); // x + y        (no bootstrap: Observation 1)
    // LUT evaluates an arbitrary function while refreshing noise (PBS).
    let lut = make_lut_poly(&TEST1, |m| m.saturating_sub(4) * 2);
    let result = ctx.pbs(&sum, &server_keys, &lut);

    // --- client: decrypt.
    let out = decrypt_message(&result, &sk);
    println!("relu(3 + 2 - 4) * 2 = {out}");
    assert_eq!(out, 2);
    println!("quickstart OK");
}
