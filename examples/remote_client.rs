//! Remote serving end-to-end: a client that keeps its own keys.
//!
//! The deployment shape the wire layer exists for — and the regression
//! driver for the key-pinning bugfix:
//!
//! 1. The client generates its OWN key pair. The server's seeded tenant
//!    stores cannot derive it: resolving this session from the master
//!    seed would mint *different* keys and every decryption would be
//!    garbage. Uploading + pinning is the only correct path.
//! 2. The client connects over framed TCP, learns the server's parameter
//!    set from the HELLO handshake, and streams its server keys up in
//!    chunks (`wire::codec` — the full key set is never resident twice).
//! 3. It submits encrypted requests under the uploaded session. The
//!    cluster routes round-robin, so every shard serves this session —
//!    which only works because `Cluster::register_session` broadcast the
//!    upload to every shard store.
//! 4. Every decrypted answer must match the plaintext interpreter, the
//!    remote ciphertexts must be bitwise identical to an in-process
//!    `Cluster::submit` of the same inputs, and the shard stores must
//!    report ZERO key regenerations — the uploaded keys stayed pinned.
//!
//!     cargo run --release --example remote_client
//!     # flags: -- --width 8 --requests 4 --shards 2
//!     #        --addr HOST:PORT   (connect to a running
//!     #                            `taurus serve --listen` instead of
//!     #                            spawning a loopback server; the
//!     #                            quickstart program + TEST1 apply)
//!
//! Results are recorded in EXPERIMENTS.md §Wire.

use std::sync::Arc;
use std::time::Instant;

use taurus::cluster::{Cluster, ClusterOptions, PlacementPolicy, StoreFactory};
use taurus::coordinator::CoordinatorOptions;
use taurus::ir::builder::ProgramBuilder;
use taurus::ir::interp;
use taurus::ir::Program;
use taurus::params::{self, ParamSet};
use taurus::tenant::{KeyStore, SeededTenantStore, SessionId};
use taurus::tfhe::keycache;
use taurus::tfhe::pbs::{decrypt_message, encrypt_message};
use taurus::util::rng::Rng;
use taurus::wire::{Client, WireServer, WireServerOptions};

fn flag(name: &str) -> Option<String> {
    std::env::args().skip_while(|a| a != name).nth(1)
}

/// The session the client uploads under — any u64 the client picks; it
/// is NOT one of the seeded tenant ids the server can derive.
const SESSION: u64 = 0xC11E;

/// Client-side key seed. Deliberately unrelated to the server stores'
/// master seed: the server cannot re-derive this material.
const CLIENT_SEED: u64 = 0x0DD_C0DE;

/// The quickstart program (`taurus serve` compiles the same one): fanout
/// d = 2x + y + 1 into relu(d) and sign(d), so KS-dedup is live.
fn demo_program(p: &ParamSet) -> Program {
    let mut b = ProgramBuilder::new("remote-demo", p.width);
    let x = b.input();
    let y = b.input();
    let d = b.dot(vec![x, y], vec![2, 1], 1);
    let r = b.relu(d, 3);
    let s = b.lut_fn(d, |m| u64::from(m > 3));
    b.outputs(&[r, s]);
    b.finish()
}

fn main() {
    let width: usize = flag("--width").and_then(|v| v.parse().ok()).unwrap_or(3);
    let requests: usize = flag("--requests").and_then(|v| v.parse().ok()).unwrap_or(8).max(1);
    let shards: usize = flag("--shards").and_then(|v| v.parse().ok()).unwrap_or(2).max(1);
    let addr = flag("--addr");

    println!("== taurus remote client (wire protocol) ==");

    // Loopback mode spawns the server half in-process: a round-robin
    // sharded cluster whose stores derive seeded tenants — but NOT this
    // client's keys — behind a TCP front end on an ephemeral port.
    let loopback = addr.is_none();
    let (server_ctx, connect_to) = if loopback {
        let p = params::select_for_width(width);
        let factory: StoreFactory = Arc::new(move |_shard| {
            Arc::new(SeededTenantStore::new(p, 0x5EED_FACE, 4)) as Arc<dyn KeyStore>
        });
        let cluster = Arc::new(Cluster::start_with_store_factory(
            demo_program(p),
            factory,
            ClusterOptions {
                shards,
                // Round-robin on purpose: every shard must serve the
                // uploaded session, proving the cross-shard broadcast.
                policy: PlacementPolicy::RoundRobin,
                queue_depth: None,
                coordinator: CoordinatorOptions { workers: 1, ..Default::default() },
                qos: None,
            },
        ));
        let server = WireServer::start(cluster.clone(), "127.0.0.1:0", WireServerOptions::default())
            .expect("bind loopback listener");
        let addr = server.local_addr().to_string();
        println!("loopback server: {addr} ({} x {shards} shards, round-robin)", p.name);
        (Some((server, cluster)), addr)
    } else {
        (None, addr.expect("--addr checked above"))
    };

    // Connect; the handshake tells us what parameter set to encrypt for.
    let mut client = Client::connect(&connect_to).expect("connect");
    let p = client.params();
    let prog = demo_program(p);
    println!("connected      : {connect_to} serves {} (width {})", p.name, p.width);

    // The client's own keys. `keycache` generates them chunked and
    // multi-worker (WIDE widths are minutes monolithic, seconds cached).
    let t0 = Instant::now();
    let keys = keycache::get(p, CLIENT_SEED);
    println!("client keygen  : {} in {:.2}s (client-held, server cannot derive)", p.name, t0.elapsed().as_secs_f64());

    // Stream the server-key half up. After the commit ACK the keys are
    // pinned on every shard store under our session.
    let t0 = Instant::now();
    client.upload_keys(SessionId(SESSION), &keys.server).expect("key upload");
    let mb = (p.bsk_bytes() + p.ksk_bytes()) as f64 / (1024.0 * 1024.0);
    let dt = t0.elapsed().as_secs_f64();
    println!("key upload     : {mb:.1} MB in {dt:.2}s ({:.1} MB/s), pinned cluster-wide", mb / dt.max(1e-9));

    // Drive encrypted requests through the socket; in loopback mode the
    // same inputs also go through `Cluster::submit` in-process and the
    // two answers must agree BITWISE — the wire layer is a transport,
    // not a transform.
    let mut rng = Rng::new(0x5151);
    let mut correct = 0usize;
    for i in 0..requests {
        let (mx, my) = ((i as u64) % 4, (i as u64 * 3) % 4);
        let expected = interp::eval(&prog, &[mx, my]);
        let inputs =
            vec![encrypt_message(mx, &keys.sk, &mut rng), encrypt_message(my, &keys.sk, &mut rng)];
        let remote = client.submit(SessionId(SESSION), &inputs).expect("remote submit");
        if let Some((_, cluster)) = &server_ctx {
            let local = cluster
                .submit(SessionId(SESSION), inputs.clone())
                .expect("in-process submit")
                .recv()
                .expect("in-process response");
            assert!(remote == local, "request {i}: remote ciphertexts differ from in-process");
        }
        let got: Vec<u64> = remote.iter().map(|c| decrypt_message(c, &keys.sk)).collect();
        assert_eq!(got, expected, "request {i}: decrypted output diverges from the interpreter");
        correct += 1;
    }
    println!("requests       : {correct}/{requests} correct (decrypt == interpreter)");

    if let Some((mut server, cluster)) = server_ctx {
        // The fix under test: uploaded keys were never silently
        // regenerated from the master seed, on any shard.
        let snap = cluster.snapshot();
        assert_eq!(snap.key_regenerations, 0, "uploaded session keys must never regenerate");
        assert!(snap.key_pinned >= shards, "every shard store pins the uploaded keys");
        let per_shard = cluster.shard_snapshots();
        let served: Vec<usize> = per_shard.iter().map(|s| s.requests).collect();
        println!(
            "shards         : {} requests per shard {:?}, {} pinned entries, 0 regenerations",
            snap.requests, served, snap.key_pinned
        );
        if requests >= 2 * shards {
            assert!(
                served.iter().all(|&r| r > 0),
                "round-robin must exercise every shard's copy of the uploaded keys"
            );
        }
        server.shutdown();
        if let Ok(mut c) = Arc::try_unwrap(cluster) {
            c.shutdown();
        }
    }
    println!("remote client OK (bitwise identical to in-process, keys pinned)");
}
