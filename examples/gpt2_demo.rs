//! Privacy-preserving GPT-2 on the Taurus model — the paper's headline
//! demonstration ("the first accelerator to demonstrate privacy-preserving
//! inference with large language models such as GPT-2").
//!
//!     cargo run --release --example gpt2_demo [-- --heads 12]
//!
//! Builds the quantized GPT-2 workload (single- or 12-head), compiles it
//! with the Taurus compiler (KS-dedup + ACC-dedup + batching), and reports
//! the model's runtime against the CPU/GPU baselines, including the
//! dual-A5000 OOM the paper hits on the 12-head variant.

use taurus::arch::{simulate, TaurusConfig};
use taurus::baselines::{cpu_model, gpu_model, DUAL_A5000, EPYC_7R13};
use taurus::compiler::compile;
use taurus::workloads::gpt2::gpt2;

fn main() {
    let heads: usize = std::env::args()
        .skip_while(|a| a != "--heads")
        .nth(1)
        .and_then(|v| v.parse().ok())
        .unwrap_or(1);
    let params = if heads <= 1 { &taurus::params::GPT2 } else { &taurus::params::GPT2_12HEAD };
    println!("building quantized GPT-2 ({heads} head{})...", if heads == 1 { "" } else { "s" });
    let prog = gpt2(heads, 1);
    println!("  {} PBS over {} dependent levels", prog.pbs_count(), prog.pbs_depth());

    let cfg = TaurusConfig::default();
    let c = compile(&prog, params, cfg.batch_capacity());
    println!(
        "  compiler: KS-dedup {} -> {} ({:.1}%), ACC-dedup {:.2}% storage saved",
        c.ks_dedup.before,
        c.ks_dedup.after,
        c.ks_dedup.reduction_pct(),
        c.acc_dedup.bytes_reduction_pct()
    );

    let r = simulate(&c, &cfg);
    let cpu = cpu_model::program_seconds(&c, &EPYC_7R13);
    let paper = if heads <= 1 { (1218.13, "721.14 s", 860.94) } else { (23685.14, "OOM", 10649.33) };
    println!("\n  Taurus  : {:>10.2} ms   (paper {:.2} ms)", r.seconds * 1e3, paper.2);
    println!("  CPU     : {:>10.2} s    (paper {:.2} s)", cpu, paper.0);
    if gpu_model::fits(&c, &DUAL_A5000) {
        println!(
            "  GPU     : {:>10.2} s    (paper {})",
            gpu_model::program_seconds(&c, &DUAL_A5000),
            paper.1
        );
    } else {
        println!(
            "  GPU     : OOM — working set {:.1} GB > {} GB   (paper {})",
            gpu_model::working_set_bytes(&c) / 1e9,
            2.0 * DUAL_A5000.mem_gb,
            paper.1
        );
    }
    println!("  speedup : {:.0}x over CPU (paper {}x)", cpu / r.seconds, if heads <= 1 { 1414 } else { 2224 });
    println!("  util    : {:.1}%,  avg BW {:.0} GB/s", r.utilization * 100.0, r.avg_bw_gbps);
}
