//! END-TO-END cluster serving driver (the repository's integration
//! proof): compile an FHE inference program ONCE, start a sharded cluster
//! (N coordinator shards behind a placement router with a bounded shared
//! admission queue), submit encrypted queries from several simulated
//! clients, check every decrypted answer against the plaintext
//! interpreter, and report aggregate + per-shard latency/throughput.
//! Results are recorded in EXPERIMENTS.md §Change 6.
//!
//!     cargo run --release --example serving
//!     # flags: -- --requests 32 --shards 2 --workers 1
//!     #        --policy round-robin|least-outstanding|consistent-hash
//!     #        --queue-depth 8 --backend native|xla
//!     # (xla needs `make artifacts` and the `xla` feature)

use std::collections::VecDeque;
use std::sync::Arc;
use std::time::Instant;

use taurus::cluster::{Cluster, ClusterOptions, ClusterResponse, PlacementPolicy};
use taurus::coordinator::{BackendKind, CoordinatorOptions};
use taurus::ir::builder::ProgramBuilder;
use taurus::ir::interp;
use taurus::params::TEST1;
use taurus::tfhe::pbs::{decrypt_message, encrypt_message};
use taurus::tfhe::{SecretKeys, ServerKeys};
use taurus::util::rng::Rng;

fn flag(name: &str) -> Option<String> {
    std::env::args().skip_while(|a| a != name).nth(1)
}

fn main() {
    let requests: usize = flag("--requests").and_then(|v| v.parse().ok()).unwrap_or(24);
    let shards: usize = flag("--shards").and_then(|v| v.parse().ok()).unwrap_or(2).max(1);
    let workers: usize = flag("--workers").and_then(|v| v.parse().ok()).unwrap_or(1);
    // 0 means unbounded, matching the `taurus serve` CLI.
    let queue_depth: usize = flag("--queue-depth").and_then(|v| v.parse().ok()).unwrap_or(8);
    let policy = flag("--policy")
        .and_then(|p| PlacementPolicy::parse(&p))
        .unwrap_or(PlacementPolicy::ConsistentHash);
    let use_xla = flag("--backend").as_deref() != Some("native")
        && std::path::Path::new("artifacts/manifest.json").exists();

    // The served model: a 2-layer quantized MLP head, relu(W x + b) -> LUT.
    let mut b = ProgramBuilder::new("mlp-head", TEST1.width);
    let xs = b.inputs(3);
    let h: Vec<_> = (0..3)
        .map(|j| {
            let d = b.dot(xs.clone(), vec![1, ((j % 2) as i64) * 2 - 1, 1], j as u64);
            b.relu(d, 2)
        })
        .collect();
    let logit = b.dot(h, vec![1, 1, 1], 0);
    let out = b.lut_fn(logit, |m| m.min(7));
    b.output(out);
    let prog = b.finish();

    println!("== taurus cluster serving driver ==");
    println!("program: {} ({} PBS/query, depth {})", prog.name, prog.pbs_count(), prog.pbs_depth());
    println!(
        "cluster: {shards} shards x {workers} workers, {} routing, admission depth {}",
        policy.name(),
        if queue_depth > 0 { queue_depth.to_string() } else { "unbounded".into() },
    );
    println!("backend: {}", if use_xla { "xla (AOT JAX/Pallas via PJRT)" } else { "native" });

    let mut rng = Rng::new(404);
    let t0 = Instant::now();
    let sk = SecretKeys::generate(&TEST1, &mut rng);
    let keys = Arc::new(ServerKeys::generate(&sk, &mut rng));
    println!("keygen: {:.2}s (replicated to every shard by Arc, zero copies)", t0.elapsed().as_secs_f64());

    let backend = if use_xla {
        BackendKind::Xla { artifacts_dir: "artifacts".into() }
    } else {
        BackendKind::Native
    };
    let mut cluster = Cluster::start(
        prog.clone(),
        keys,
        ClusterOptions {
            shards,
            policy,
            queue_depth: if queue_depth > 0 { Some(queue_depth) } else { None },
            coordinator: CoordinatorOptions {
                workers,
                backend,
                batch_capacity: 8,
                ..Default::default()
            },
        },
    );
    println!(
        "plan   : compiled once, shared by all shards (KS-dedup {} -> {})",
        cluster.plan().ks_dedup.before,
        cluster.plan().ks_dedup.after
    );

    // Clients: fire all queries through the admission queue (draining the
    // oldest response whenever backpressure fires), then collect.
    let clients = 6u64;
    let t0 = Instant::now();
    let mut pending: VecDeque<(ClusterResponse, u64)> = VecDeque::new();
    let mut shed = 0usize;
    let mut correct = 0usize;
    for i in 0..requests {
        let q: Vec<u64> = (0..3).map(|j| ((i + j) % 6) as u64).collect();
        let expected = interp::eval(&prog, &q)[0];
        let client_id = (i as u64) % clients;
        // Admission slots are held by the pending handles, so this
        // single-submitter client drains the oldest response whenever the
        // shared queue is at depth — backpressure without re-encrypting.
        while queue_depth > 0 && cluster.outstanding() >= queue_depth {
            shed += 1;
            let (r, exp) = pending.pop_front().expect("full queue implies pending work");
            let outs = r.recv().expect("response");
            correct += usize::from(decrypt_message(&outs[0], &sk) == exp);
        }
        let cts: Vec<_> = q.iter().map(|&m| encrypt_message(m, &sk, &mut rng)).collect();
        let resp = match cluster.submit(client_id, cts) {
            Ok(r) => r,
            Err(e) => panic!("submit failed: {e}"),
        };
        pending.push_back((resp, expected));
    }
    while let Some((r, exp)) = pending.pop_front() {
        let outs = r.recv().expect("response");
        correct += usize::from(decrypt_message(&outs[0], &sk) == exp);
    }
    let wall = t0.elapsed().as_secs_f64();

    let snap = cluster.snapshot();
    let per_shard = cluster.shard_snapshots();
    println!("\nresults ({requests} encrypted queries, {clients} clients):");
    println!("  correct      : {correct}/{requests}");
    println!("  wall         : {:.2} s  ({:.1} queries/s)", wall, requests as f64 / wall);
    println!("  backpressure : {shed} submissions deferred by the admission queue");
    println!("  p50 latency  : {:.1} ms (merged per-shard samples)", snap.p50_latency_ms);
    println!("  p99 latency  : {:.1} ms", snap.p99_latency_ms);
    println!("  mean queue   : {:.1} ms", snap.mean_queue_ms);
    println!("  batches      : {} (mean size {:.2})", snap.batches, snap.mean_batch_size);
    println!("  PBS executed : {}", snap.pbs_executed);
    println!("  per shard    : id  requests  batches  mean-batch");
    for (i, s) in per_shard.iter().enumerate() {
        println!("                 {i:<3} {:>8} {:>8} {:>10.2}", s.requests, s.batches, s.mean_batch_size);
    }
    assert_eq!(correct, requests, "all decryptions must match the interpreter");
    let sum_requests: usize = per_shard.iter().map(|s| s.requests).sum();
    assert_eq!(snap.requests, sum_requests, "merged snapshot sums the shards");
    cluster.shutdown();
    println!("cluster serving driver OK");
}
