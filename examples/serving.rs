//! END-TO-END multi-tenant cluster serving driver (the repository's
//! integration proof) — and the quickstart for the **session API**.
//!
//! # Session API quickstart
//!
//! Serving is organized around *sessions*: every client session owns its
//! own TFHE keys, and the server resolves sessions to server-key material
//! through a `tenant::KeyStore`:
//!
//! ```ignore
//! use taurus::cluster::{Cluster, ClusterOptions, PlacementPolicy, StoreFactory};
//! use taurus::tenant::{client_secret, KeyStore, SeededTenantStore, SessionId};
//!
//! // 1. One shard-local store per shard: each derives per-session server
//! //    keys from the same master seed, cached in a bounded LRU.
//! let factory: StoreFactory =
//!     Arc::new(move |_shard| Arc::new(SeededTenantStore::new(&TEST1, MASTER_SEED, CAP)) as _);
//!
//! // 2. Start the cluster; consistent-hash placement pins each session
//! //    to one shard, so its keys stay warm in that shard's cache.
//! let mut cluster = Cluster::start_with_store_factory(prog, factory, opts);
//!
//! // 3. Clients keep their own secret keys and submit per session.
//! let sk = client_secret(&TEST1, MASTER_SEED, SessionId(7));
//! let resp = cluster.submit(SessionId(7), encrypted_inputs)?;
//! let answer = decrypt_message(&resp.recv()?[0], &sk);
//!
//! // 4. Scale live: drain, rebuild the hash ring, migrate cached keys.
//! let report = cluster.reshard(shards + 2)?;
//! ```
//!
//! Single-tenant code keeps working: `Cluster::start(prog, keys, opts)`
//! wraps one `Arc<ServerKeys>` in `tenant::StaticKeys` — same bits, same
//! behavior as before the session API.
//!
//! This driver: compile an FHE inference program ONCE, start a sharded
//! cluster with per-tenant seeded stores, submit encrypted queries from
//! several tenant sessions (each encrypted under its own key), check
//! every decrypted answer against the plaintext interpreter, reshard the
//! cluster live mid-run, and report aggregate + per-shard + per-tenant
//! metrics. Results are recorded in EXPERIMENTS.md §Tenants.
//!
//!     cargo run --release --example serving
//!     # flags: -- --requests 32 --shards 2 --workers 1 --tenants 3
//!     #        --key-cache-cap 4 --queue-depth 8 --grow 1
//!     #        --policy round-robin|least-outstanding|consistent-hash

use std::collections::VecDeque;
use std::sync::Arc;
use std::time::Instant;

use taurus::cluster::{Cluster, ClusterOptions, ClusterResponse, PlacementPolicy, StoreFactory};
use taurus::coordinator::CoordinatorOptions;
use taurus::ir::builder::ProgramBuilder;
use taurus::ir::interp;
use taurus::params::TEST1;
use taurus::tenant::{client_secret, KeyStore, SeededTenantStore, SessionId};
use taurus::tfhe::pbs::{decrypt_message, encrypt_message};
use taurus::tfhe::SecretKeys;
use taurus::util::rng::Rng;

fn flag(name: &str) -> Option<String> {
    std::env::args().skip_while(|a| a != name).nth(1)
}

fn main() {
    let requests: usize = flag("--requests").and_then(|v| v.parse().ok()).unwrap_or(24);
    let shards: usize = flag("--shards").and_then(|v| v.parse().ok()).unwrap_or(2).max(1);
    let workers: usize = flag("--workers").and_then(|v| v.parse().ok()).unwrap_or(1);
    let tenants: usize = flag("--tenants").and_then(|v| v.parse().ok()).unwrap_or(3).max(1);
    let cache_cap: usize = flag("--key-cache-cap").and_then(|v| v.parse().ok()).unwrap_or(4).max(1);
    // Shards added by the live reshard halfway through the run.
    let grow: usize = flag("--grow").and_then(|v| v.parse().ok()).unwrap_or(1);
    // 0 means unbounded, matching the `taurus serve` CLI.
    let queue_depth: usize = flag("--queue-depth").and_then(|v| v.parse().ok()).unwrap_or(8);
    let policy = flag("--policy")
        .and_then(|p| PlacementPolicy::parse(&p))
        .unwrap_or(PlacementPolicy::ConsistentHash);
    // The session API serves natively: the XLA backend bakes keys into
    // device buffers and cannot rebind per-tenant key sets. Say so rather
    // than silently ignoring the historical flag; single-tenant XLA
    // serving lives in `taurus serve --backend xla`.
    if flag("--backend").as_deref() == Some("xla") {
        eprintln!(
            "note: --backend xla is unsupported by the multi-tenant session driver \
             (per-tenant key rebinding); serving natively. Use `taurus serve --backend xla` \
             for single-tenant XLA."
        );
    }

    // The served model: a 2-layer quantized MLP head, relu(W x + b) -> LUT.
    let mut b = ProgramBuilder::new("mlp-head", TEST1.width);
    let xs = b.inputs(3);
    let h: Vec<_> = (0..3)
        .map(|j| {
            let d = b.dot(xs.clone(), vec![1, ((j % 2) as i64) * 2 - 1, 1], j as u64);
            b.relu(d, 2)
        })
        .collect();
    let logit = b.dot(h, vec![1, 1, 1], 0);
    let out = b.lut_fn(logit, |m| m.min(7));
    b.output(out);
    let prog = b.finish();

    println!("== taurus multi-tenant cluster serving driver ==");
    println!("program: {} ({} PBS/query, depth {})", prog.name, prog.pbs_count(), prog.pbs_depth());
    println!(
        "cluster: {shards} shards x {workers} workers, {} routing, admission depth {}, {tenants} tenant sessions (cache cap {cache_cap}/shard)",
        policy.name(),
        if queue_depth > 0 { queue_depth.to_string() } else { "unbounded".into() },
    );

    // Client side: each tenant session keeps its own secret keys.
    let master_seed = 0x5E55_0404u64;
    let t0 = Instant::now();
    let sks: Vec<SecretKeys> =
        (0..tenants as u64).map(|t| client_secret(&TEST1, master_seed, SessionId(t))).collect();
    println!(
        "client keys: {tenants} tenant secrets derived in {:.2}s (server keys derive shard-side on first touch)",
        t0.elapsed().as_secs_f64()
    );

    // Server side: one seeded store per shard; the factory also mints
    // stores for shards added by reshard.
    let factory: StoreFactory = Arc::new(move |_shard| {
        Arc::new(SeededTenantStore::new(&TEST1, master_seed, cache_cap)) as Arc<dyn KeyStore>
    });
    let mut cluster = Cluster::start_with_store_factory(
        prog.clone(),
        factory,
        ClusterOptions {
            shards,
            policy,
            queue_depth: if queue_depth > 0 { Some(queue_depth) } else { None },
            coordinator: CoordinatorOptions { workers, batch_capacity: 8, ..Default::default() },
            qos: None,
        },
    );
    println!(
        "plan   : compiled once, shared by all shards (KS-dedup {} -> {})",
        cluster.plan().ks_dedup.before,
        cluster.plan().ks_dedup.after
    );

    // Tenants fire queries through the admission queue (draining the
    // oldest response whenever backpressure fires), then collect. Halfway
    // through, the cluster reshards live.
    let mut rng = Rng::new(404);
    let t0 = Instant::now();
    let mut pending: VecDeque<(ClusterResponse, u64, usize)> = VecDeque::new();
    let mut shed = 0usize;
    let mut correct = 0usize;
    let reshard_at = if grow > 0 { requests / 2 } else { usize::MAX };
    for i in 0..requests {
        if i == reshard_at {
            // Live reshard: drain in-flight work first so no response is
            // lost, then migrate the key-cache entries the new ring
            // re-homes.
            while let Some((r, exp, t)) = pending.pop_front() {
                let outs = r.recv().expect("response");
                correct += usize::from(decrypt_message(&outs[0], &sks[t]) == exp);
            }
            let report =
                cluster.reshard(shards + grow).expect("factory-backed cluster reshards freely");
            println!(
                "reshard: {} -> {} shards, {}/{} cached tenant keys migrated with the ring",
                report.old_shards, report.new_shards, report.migrated, report.resident_before
            );
        }
        let t = i % tenants;
        let q: Vec<u64> = (0..3).map(|j| ((i + j) % 6) as u64).collect();
        let expected = interp::eval(&prog, &q)[0];
        // Admission slots are held by the pending handles, so this
        // single-submitter client drains the oldest response whenever the
        // shared queue is at depth — backpressure without re-encrypting.
        while queue_depth > 0 && cluster.outstanding() >= queue_depth {
            shed += 1;
            let (r, exp, pt) = pending.pop_front().expect("full queue implies pending work");
            let outs = r.recv().expect("response");
            correct += usize::from(decrypt_message(&outs[0], &sks[pt]) == exp);
        }
        let cts: Vec<_> = q.iter().map(|&m| encrypt_message(m, &sks[t], &mut rng)).collect();
        let resp = match cluster.submit(SessionId(t as u64), cts) {
            Ok(r) => r,
            Err(e) => panic!("submit failed: {e}"),
        };
        pending.push_back((resp, expected, t));
    }
    while let Some((r, exp, t)) = pending.pop_front() {
        let outs = r.recv().expect("response");
        correct += usize::from(decrypt_message(&outs[0], &sks[t]) == exp);
    }
    let wall = t0.elapsed().as_secs_f64();

    let snap = cluster.snapshot();
    let per_shard = cluster.shard_snapshots();
    println!("\nresults ({requests} encrypted queries, {tenants} tenant sessions):");
    println!("  correct      : {correct}/{requests}");
    println!("  wall         : {:.2} s  ({:.1} queries/s)", wall, requests as f64 / wall);
    println!("  backpressure : {shed} submissions deferred by the admission queue");
    println!("  p50 latency  : {:.1} ms (merged per-shard samples)", snap.p50_latency_ms);
    println!("  p99 latency  : {:.1} ms", snap.p99_latency_ms);
    println!("  mean queue   : {:.1} ms", snap.mean_queue_ms);
    println!("  batches      : {} (mean size {:.2}, {} keyed splits)", snap.batches, snap.mean_batch_size, snap.keyed_batch_splits);
    println!("  PBS executed : {}", snap.pbs_executed);
    println!(
        "  key caches   : {} hits / {} misses / {} evictions / {} regenerations, {} resident",
        snap.key_hits, snap.key_misses, snap.key_evictions, snap.key_regenerations, snap.key_resident
    );
    let per_tenant: Vec<String> =
        snap.session_requests.iter().map(|(s, n)| format!("s{s}:{n}")).collect();
    println!("  per tenant   : {}", per_tenant.join("  "));
    println!("  per shard    : id  requests  batches  mean-batch  keys-resident");
    for (i, s) in per_shard.iter().enumerate() {
        println!(
            "                 {i:<3} {:>8} {:>8} {:>10.2} {:>13}",
            s.requests, s.batches, s.mean_batch_size, s.key_resident
        );
    }
    assert_eq!(correct, requests, "all decryptions must match the interpreter");
    let tenant_total: u64 = snap.session_requests.values().sum();
    assert_eq!(tenant_total as usize, requests, "per-tenant counts sum to the total");
    cluster.shutdown();
    println!("multi-tenant cluster serving driver OK");
}
