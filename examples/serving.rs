//! END-TO-END serving driver (the repository's integration proof):
//! compile an FHE inference program, start the coordinator with the **XLA
//! backend** (AOT JAX/Pallas artifacts executed via PJRT — python is not
//! running), submit batched encrypted queries from a client thread, check
//! every decrypted answer against the plaintext interpreter, and report
//! latency/throughput. Results are recorded in EXPERIMENTS.md.
//!
//!     make artifacts && cargo run --release --example serving
//!     # flags: -- --requests 32 --workers 2 --backend native|xla

use std::sync::Arc;
use std::time::Instant;

use taurus::coordinator::{BackendKind, Coordinator, CoordinatorOptions};
use taurus::ir::builder::ProgramBuilder;
use taurus::ir::interp;
use taurus::params::TEST1;
use taurus::tfhe::pbs::{decrypt_message, encrypt_message};
use taurus::tfhe::{SecretKeys, ServerKeys};
use taurus::util::rng::Rng;

fn flag(name: &str) -> Option<String> {
    std::env::args().skip_while(|a| a != name).nth(1)
}

fn main() {
    let requests: usize = flag("--requests").and_then(|v| v.parse().ok()).unwrap_or(24);
    let workers: usize = flag("--workers").and_then(|v| v.parse().ok()).unwrap_or(2);
    let use_xla = flag("--backend").as_deref() != Some("native")
        && std::path::Path::new("artifacts/manifest.json").exists();

    // The served model: a 2-layer quantized MLP head, relu(W x + b) -> LUT.
    let mut b = ProgramBuilder::new("mlp-head", TEST1.width);
    let xs = b.inputs(3);
    let h: Vec<_> = (0..3)
        .map(|j| {
            let d = b.dot(xs.clone(), vec![1, ((j % 2) as i64) * 2 - 1, 1], j as u64);
            b.relu(d, 2)
        })
        .collect();
    let logit = b.dot(h, vec![1, 1, 1], 0);
    let out = b.lut_fn(logit, |m| m.min(7));
    b.output(out);
    let prog = b.finish();

    println!("== taurus serving driver ==");
    println!("program: {} ({} PBS/query, depth {})", prog.name, prog.pbs_count(), prog.pbs_depth());
    println!("backend: {}", if use_xla { "xla (AOT JAX/Pallas via PJRT)" } else { "native" });

    let mut rng = Rng::new(404);
    let t0 = Instant::now();
    let sk = SecretKeys::generate(&TEST1, &mut rng);
    let keys = Arc::new(ServerKeys::generate(&sk, &mut rng));
    println!("keygen: {:.2}s", t0.elapsed().as_secs_f64());

    let backend = if use_xla {
        BackendKind::Xla { artifacts_dir: "artifacts".into() }
    } else {
        BackendKind::Native
    };
    let mut coord = Coordinator::start(
        prog.clone(),
        keys,
        CoordinatorOptions { workers, backend, batch_capacity: 8, ..Default::default() },
    );

    // Client: fire all queries, then collect.
    let t0 = Instant::now();
    let mut pending = Vec::new();
    let mut expected = Vec::new();
    for i in 0..requests {
        let q: Vec<u64> = (0..3).map(|j| ((i + j) % 6) as u64).collect();
        expected.push(interp::eval(&prog, &q)[0]);
        let cts: Vec<_> = q.iter().map(|&m| encrypt_message(m, &sk, &mut rng)).collect();
        pending.push(coord.submit(cts).expect("submit"));
    }
    let mut correct = 0;
    for (rx, exp) in pending.iter().zip(&expected) {
        let outs = rx.recv().expect("response");
        correct += usize::from(decrypt_message(&outs[0], &sk) == *exp);
    }
    let wall = t0.elapsed().as_secs_f64();
    let snap = coord.metrics.snapshot();
    println!("\nresults ({requests} encrypted queries, {workers} workers):");
    println!("  correct      : {correct}/{requests}");
    println!("  wall         : {:.2} s  ({:.1} queries/s)", wall, requests as f64 / wall);
    println!("  p50 latency  : {:.1} ms", snap.p50_latency_ms);
    println!("  p99 latency  : {:.1} ms", snap.p99_latency_ms);
    println!("  mean queue   : {:.1} ms", snap.mean_queue_ms);
    println!("  batches      : {} (mean size {:.2})", snap.batches, snap.mean_batch_size);
    println!("  PBS executed : {}", snap.pbs_executed);
    assert_eq!(correct, requests, "all decryptions must match the interpreter");
    coord.shutdown();
    println!("serving driver OK");
}
