//! Encrypted CNN inference, end to end:
//! 1. run a *small* CNN functionally on encrypted data (native TFHE) and
//!    check it against the plaintext interpreter;
//! 2. compile the paper's CNN-20 at its Table II parameter set and report
//!    the Taurus model's runtime/utilization plus the dedup statistics.
//!
//!     cargo run --release --example cnn_inference

use taurus::arch::{simulate, TaurusConfig};
use taurus::baselines::{cpu_model, EPYC_7R13};
use taurus::compiler::{compile, Engine, NativePbsBackend};
use taurus::ir::interp;
use taurus::params::{CNN20, TEST1};
use taurus::tfhe::pbs::{decrypt_message, encrypt_message};
use taurus::tfhe::{SecretKeys, ServerKeys};
use taurus::util::rng::Rng;
use taurus::workloads;

fn main() {
    // ---- Part 1: functional encrypted inference on a 3-layer CNN.
    let mut rng = Rng::new(21);
    println!("[1/2] functional: 3-layer CNN at TEST1 on encrypted inputs");
    let sk = SecretKeys::generate(&TEST1, &mut rng);
    let keys = ServerKeys::generate(&sk, &mut rng);
    let small = build_small_cnn();
    let n_inputs = small.input_count();
    let inputs: Vec<u64> = (0..n_inputs as u64).map(|i| (i * 3 + 1) % 8).collect();
    let cts: Vec<_> = inputs.iter().map(|&m| encrypt_message(m, &sk, &mut rng)).collect();
    // Schedule-driven execution: the compiled plan shares key switches
    // across fanout and fuses same-accumulator rotations per level.
    let plan = compile(&small, &TEST1, 48usize);
    let mut eng = Engine::new(NativePbsBackend::new(&keys));
    let t0 = std::time::Instant::now();
    let outs = eng.run_plan(&plan, &cts);
    let secs = t0.elapsed().as_secs_f64();
    let got: Vec<u64> = outs.iter().map(|c| decrypt_message(c, &sk)).collect();
    let expected = interp::eval(&small, &inputs);
    assert_eq!(got, expected, "encrypted inference must match plaintext");
    println!(
        "  {} PBS in {:.2}s ({:.1} ms/PBS) — logits {:?} match plaintext",
        small.pbs_count(),
        secs,
        secs * 1e3 / small.pbs_count() as f64,
        got
    );
    let st = eng.take_exec_stats();
    println!(
        "  plan: {} KS (node-walk would pay {}), {} fused BR sweeps",
        st.ks_ops, plan.ks_dedup.before, st.br_calls
    );

    // ---- Part 2: the paper's CNN-20 on the Taurus model.
    println!("\n[2/2] Taurus model: CNN-20 at Table II parameters");
    let w = workloads::by_name("CNN-20 (PTQ)").unwrap();
    let prog = (w.build)(1);
    let cfg = TaurusConfig::default();
    let c = compile(&prog, &CNN20, cfg.batch_capacity());
    let r = simulate(&c, &cfg);
    let cpu = cpu_model::program_seconds(&c, &EPYC_7R13);
    println!("  PBS: {}  depth: {}", prog.pbs_count(), prog.pbs_depth());
    println!("  ACC-dedup: {:.2}% GLWE storage saved", c.acc_dedup.bytes_reduction_pct());
    println!(
        "  Taurus {:.2} ms (paper 11.60) | CPU model {:.2} s (paper 3.85) | speedup {:.0}x (paper 331x)",
        r.seconds * 1e3,
        cpu,
        cpu / r.seconds
    );
    println!("  utilization {:.1}%  avg BW {:.0} GB/s", r.utilization * 100.0, r.avg_bw_gbps);
}

/// 3-layer, 6-neuron CNN at width 3 (TEST1) — same generator structure as
/// `workloads::cnn` scaled to the functional test parameter set.
fn build_small_cnn() -> taurus::ir::Program {
    use taurus::ir::builder::ProgramBuilder;
    use taurus::ir::LutTable;
    let mut b = ProgramBuilder::new("cnn-small", 3);
    let relu = LutTable::from_fn(3, |m| m.saturating_sub(2).min(7));
    let mut layer = b.inputs(6);
    for l in 0..3 {
        let prev = layer.clone();
        layer = (0..6)
            .map(|j| {
                let ins = vec![prev[j % 6], prev[(j + 1) % 6], prev[(j + 2) % 6]];
                let ws = vec![1, ((l + j) % 3) as i64 - 1, 1];
                let acc = b.dot(ins, ws, 0);
                b.lut(acc, relu.clone())
            })
            .collect();
    }
    let outs: Vec<_> = layer.iter().take(3).copied().collect();
    let logit = b.dot(outs, vec![1, 1, 1], 0);
    b.output(logit);
    b.finish()
}
