"""TFHE parameter sets shared by the L1/L2 build path.

These mirror `rust/src/params/mod.rs` exactly — the Rust runtime feeds keys
into the AOT artifacts, so layouts and decomposition conventions must agree
bit-for-bit. Conventions (identical on both sides):

  * torus modulus q = 2^64 (u64, wrapping arithmetic);
  * gadget digit j of a torus value has weight q / B^(j+1), j = 0 is the
    most significant digit, digits are balanced in [-B/2, B/2);
  * GGSW row order: row r = c * level + j where c indexes the GLWE
    polynomial (mask polys first, body last) and j the gadget level;
  * negacyclic FFT: z_j = (p_j + i p_{j+N/2}) * twist_j with
    twist_j = exp(-i*pi*j/N), transformed by an N/2-point complex FFT
    (evaluates P at the primitive 2N-th roots zeta^(4k+1));
  * blind rotation is CMUX-based with mod-switch to 2N;
  * PBS order is **key-switch first** (paper §II-B): ciphertexts at rest
    live at the long dimension k*N.
"""

from dataclasses import dataclass


@dataclass(frozen=True)
class ParamSet:
    name: str
    # LWE (short) dimension n.
    n: int
    # GLWE polynomial degree N (power of two) and dimension k.
    N: int
    k: int
    # PBS (BSK) gadget decomposition: base 2^bsk_base_log, bsk_level digits.
    bsk_base_log: int
    bsk_level: int
    # Key-switch gadget decomposition.
    ks_base_log: int
    ks_level: int
    # Message width in bits (excluding the padding bit).
    width: int
    # Noise stddevs as fractions of the torus.
    lwe_noise: float
    glwe_noise: float

    @property
    def half_n(self) -> int:
        return self.N // 2

    @property
    def long_dim(self) -> int:
        return self.k * self.N

    @property
    def plaintext_modulus(self) -> int:
        # Message space including the padding bit.
        return 1 << (self.width + 1)

    @property
    def delta(self) -> int:
        # Encoding scale: message m is encoded as m * delta.
        return 1 << (64 - self.width - 1)

    @property
    def ggsw_rows(self) -> int:
        return (self.k + 1) * self.bsk_level


# Fast functional-test parameters (insecure: sized for test speed, noise
# chosen so that decryption failure probability is negligible; security is
# NOT a goal of the unit-test sets — see DESIGN.md).
TEST1 = ParamSet(
    name="test1",
    n=128,
    N=512,
    k=1,
    bsk_base_log=8,
    bsk_level=3,
    ks_base_log=4,
    ks_level=6,
    width=3,
    lwe_noise=2.0**-25,
    glwe_noise=2.0**-40,
)

# A second, wider test set exercising k=1 with larger N (shape of the
# paper's CNN-20 entry scaled down in n for test speed).
TEST2 = ParamSet(
    name="test2",
    n=256,
    N=2048,
    k=1,
    bsk_base_log=12,
    bsk_level=2,
    ks_base_log=4,
    ks_level=6,
    width=5,
    lwe_noise=2.0**-30,
    glwe_noise=2.0**-45,
)

# Wide-width functional sets (mirror rust/src/params/mod.rs WIDE8/WIDE10):
# the paper's headline 8/10-bit widths at TEST-scale security. The gadget
# keeps two moderate digits — a single 2^23+ digit at N = 16k/32k would
# push the f64-FFT convolution error (~ n*l*N^2*B^2 * 2^-106 variance) to
# the decision boundary.
WIDE8 = ParamSet(
    name="wide8",
    n=128,
    N=16384,
    k=1,
    bsk_base_log=12,
    bsk_level=2,
    ks_base_log=8,
    ks_level=3,
    width=8,
    lwe_noise=2.0**-30,
    glwe_noise=2.0**-48,
)

WIDE10 = ParamSet(
    name="wide10",
    n=64,
    N=32768,
    k=1,
    bsk_base_log=13,
    bsk_level=2,
    ks_base_log=8,
    ks_level=3,
    width=10,
    lwe_noise=2.0**-32,
    glwe_noise=2.0**-52,
)

ALL = {p.name: p for p in (TEST1, TEST2, WIDE8, WIDE10)}

# Parameter sets AOT-compiled into artifacts/ by default. TEST1 is the set
# the Rust integration tests and the serving example run with end-to-end.
AOT_SETS = [TEST1]
