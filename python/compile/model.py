"""L2: the PBS compute graph in JAX, calling the L1 Pallas kernels.

Two computations are exported per parameter set (mirroring the paper's
BRU/LPU functional split, Fig. 8):

  * ``blind_rotate``  — mod-switch + CMUX blind-rotation loop (BRU side);
  * ``keyswitch``     — long-LWE -> short-LWE gadget key switch (LPU side).

Both are lowered once by ``aot.py`` to HLO text and executed from the Rust
runtime; python never runs on the request path. Conventions (twist, gadget
digits, GGSW row order) are locked in ``params.py`` and must match
``rust/src/tfhe`` bit-for-bit.
"""

import jax

jax.config.update("jax_enable_x64", True)

import functools

import jax.numpy as jnp

from .params import ParamSet
from .kernels import ref as kref
from .kernels.decompose import decompose as decompose_pallas
from .kernels.fourier_mac import fourier_mac as fourier_mac_pallas

U64 = jnp.uint64
I64 = jnp.int64
_Q = float(2**64)


# --------------------------------------------------------------------------
# Negacyclic FFT (same double-real convention as tfhe_np / rust tfhe::fft).
# --------------------------------------------------------------------------

def twist(N: int):
    j = jnp.arange(N // 2)
    return jnp.exp(-1j * jnp.pi * j / N)


def nfft(p_signed, tw):
    N = p_signed.shape[-1]
    z = (p_signed[..., : N // 2] - 1j * p_signed[..., N // 2 :]) * tw
    return jnp.fft.fft(z, axis=-1)


def nifft(Z, tw):
    z = jnp.fft.ifft(Z, axis=-1) * jnp.conj(tw)
    return jnp.concatenate([z.real, -z.imag], axis=-1)


def u64_to_signed_f64(x):
    return jax.lax.bitcast_convert_type(x, I64).astype(jnp.float64)


def f64_to_u64(x):
    """Round mod 2^64 (values may exceed the 64-bit range)."""
    r = x - jnp.round(x * (1.0 / _Q)) * _Q
    return jax.lax.bitcast_convert_type(jnp.round(r).astype(I64), U64)


# --------------------------------------------------------------------------
# PBS building blocks.
# --------------------------------------------------------------------------

def modswitch(ct, N: int):
    """Torus u64 -> Z_{2N} with rounding."""
    two_n = 2 * N
    shift = jnp.uint64(64 - (two_n.bit_length() - 1))
    return ((((ct >> (shift - jnp.uint64(1))) + jnp.uint64(1)) >> jnp.uint64(1))
            % jnp.uint64(two_n)).astype(I64)


def rotate_glwe(glwe_u64, r, N: int):
    """Multiply every row by X^r (r traced, in [0, 2N))."""
    ext = jnp.concatenate([glwe_u64, jnp.zeros_like(glwe_u64) - glwe_u64], axis=-1)
    idx = (jnp.arange(N) - r) % (2 * N)
    return jnp.take(ext, idx, axis=-1)


def external_product(ggsw_re, ggsw_im, glwe_u64, p: ParamSet, tw,
                     use_pallas: bool = True):
    """GGSW (Fourier, (rows, k+1, N/2) re/im) box GLWE ((k+1, N) u64)."""
    if use_pallas:
        digits = decompose_pallas(glwe_u64, p.bsk_base_log, p.bsk_level)
    else:
        digits = kref.decompose_ref(glwe_u64, p.bsk_base_log, p.bsk_level)
    # (level, k+1, N) -> rows r = c*level + j.
    rows = jnp.transpose(digits, (1, 0, 2)).reshape(p.ggsw_rows, p.N)
    rows_f = nfft(rows.astype(jnp.float64), tw)
    if use_pallas:
        acc_re, acc_im = fourier_mac_pallas(rows_f.real, rows_f.imag,
                                            ggsw_re, ggsw_im)
    else:
        acc_re, acc_im = kref.fourier_mac_ref(rows_f.real, rows_f.imag,
                                              ggsw_re, ggsw_im)
    return f64_to_u64(nifft(acc_re + 1j * acc_im, tw))


def blind_rotate(ct_short, bsk_re, bsk_im, lut_poly, p: ParamSet,
                 use_pallas: bool = True):
    """Mod-switch + CMUX blind rotation.

    Args:
      ct_short: u64[n+1] short-LWE ciphertext (a..., b).
      bsk_re/bsk_im: f64[n, rows, k+1, N/2] Fourier BSK.
      lut_poly: u64[N] test polynomial (body of a trivial GLWE).
    Returns:
      u64[k+1, N] rotated accumulator GLWE.
    """
    N = p.N
    tw = twist(N)
    msw = modswitch(ct_short, N)  # i64[n+1] in [0, 2N)
    b = msw[-1]
    acc0 = jnp.zeros((p.k + 1, N), dtype=U64)
    acc0 = acc0.at[p.k].set(rotate_glwe(lut_poly[None, :], (2 * N - b) % (2 * N), N)[0])

    def body(i, acc):
        a_i = msw[i]
        diff = rotate_glwe(acc, a_i, N) - acc
        ep = external_product(bsk_re[i], bsk_im[i], diff, p, tw, use_pallas)
        return acc + ep

    return jax.lax.fori_loop(0, p.n, body, acc0)


def keyswitch(ct_long, ksk, p: ParamSet, use_pallas: bool = True):
    """LWE_{kN} -> LWE_n: out = (0, b) - sum_ij dec_j(a_i) * KSK[i,j].

    Args:
      ct_long: u64[kN+1]; ksk: u64[kN, ks_level, n+1].
    """
    a = ct_long[:-1]
    if use_pallas:
        digits = decompose_pallas(a[None, :], p.ks_base_log, p.ks_level)[:, 0, :]
    else:
        digits = kref.decompose_ref(a, p.ks_base_log, p.ks_level)
    d_u = jax.lax.bitcast_convert_type(digits, U64)  # (level, kN)
    # sum over (i, j): wrapping u64 dot.
    contrib = jnp.sum(
        d_u.transpose(1, 0)[:, :, None] * ksk, axis=(0, 1), dtype=U64
    )
    out = jnp.zeros(p.n + 1, dtype=U64).at[-1].set(ct_long[-1])
    return out - contrib


# --------------------------------------------------------------------------
# Jit-able entry points per parameter set (what aot.py lowers).
# --------------------------------------------------------------------------

def build_blind_rotate(p: ParamSet, use_pallas: bool = True):
    @functools.partial(jax.jit, donate_argnums=())
    def fn(ct_short, bsk_re, bsk_im, lut_poly):
        return (blind_rotate(ct_short, bsk_re, bsk_im, lut_poly, p, use_pallas),)

    specs = (
        jax.ShapeDtypeStruct((p.n + 1,), U64),
        jax.ShapeDtypeStruct((p.n, p.ggsw_rows, p.k + 1, p.half_n), jnp.float64),
        jax.ShapeDtypeStruct((p.n, p.ggsw_rows, p.k + 1, p.half_n), jnp.float64),
        jax.ShapeDtypeStruct((p.N,), U64),
    )
    names = ("ct_short", "bsk_re", "bsk_im", "lut_poly")
    return fn, specs, names


def build_keyswitch(p: ParamSet, use_pallas: bool = True):
    @jax.jit
    def fn(ct_long, ksk):
        return (keyswitch(ct_long, ksk, p, use_pallas),)

    specs = (
        jax.ShapeDtypeStruct((p.long_dim + 1,), U64),
        jax.ShapeDtypeStruct((p.long_dim, p.ks_level, p.n + 1), U64),
    )
    names = ("ct_long", "ksk")
    return fn, specs, names
