"""AOT: lower the L2 JAX computations to HLO **text** artifacts.

Run once at build time (`make artifacts`); the Rust runtime loads the text
via `HloModuleProto::from_text_file`. Text (not `.serialize()`) is the
interchange format because jax >= 0.5 emits protos with 64-bit instruction
ids that xla_extension 0.5.1 rejects; the text parser reassigns ids.

Usage: python -m compile.aot --out ../artifacts [--sets test1,test2]
"""

import argparse
import json
import os

import jax

jax.config.update("jax_enable_x64", True)

from jax._src.lib import xla_client as xc

from . import model
from .params import ALL, AOT_SETS


def to_hlo_text(fn, specs) -> str:
    lowered = jax.jit(fn).lower(*specs)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def export(out_dir: str, set_names=None) -> dict:
    sets = [ALL[s] for s in set_names] if set_names else AOT_SETS
    os.makedirs(out_dir, exist_ok=True)
    manifest = {"artifacts": []}
    for p in sets:
        for name, builder in (
            ("blind_rotate", model.build_blind_rotate),
            ("keyswitch", model.build_keyswitch),
        ):
            fn, specs, arg_names = builder(p)
            text = to_hlo_text(fn, specs)
            fname = f"{name}_{p.name}.hlo.txt"
            with open(os.path.join(out_dir, fname), "w") as f:
                f.write(text)
            manifest["artifacts"].append(
                {
                    "name": name,
                    "param_tag": p.name,
                    "file": fname,
                    "inputs": [
                        {
                            "name": an,
                            "dtype": str(s.dtype),
                            "shape": list(s.shape),
                        }
                        for an, s in zip(arg_names, specs)
                    ],
                    "params": {
                        "n": p.n,
                        "N": p.N,
                        "k": p.k,
                        "bsk_base_log": p.bsk_base_log,
                        "bsk_level": p.bsk_level,
                        "ks_base_log": p.ks_base_log,
                        "ks_level": p.ks_level,
                        "width": p.width,
                        "lwe_noise": p.lwe_noise,
                        "glwe_noise": p.glwe_noise,
                    },
                }
            )
            print(f"wrote {fname} ({len(text)} chars)")
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote manifest.json ({len(manifest['artifacts'])} artifacts)")
    return manifest


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--sets", default=None,
                    help="comma-separated param set names (default: AOT_SETS)")
    args = ap.parse_args()
    export(args.out, args.sets.split(",") if args.sets else None)


if __name__ == "__main__":
    main()
