"""Pallas kernel: Fourier-domain external-product MAC.

This is the compute hot-spot of blind rotation — the paper's BRU performs
512 BSK multiplications per cycle on exactly this contraction (§IV-A). Per
frequency bin `h` it is a (1 x R) · (R x C) complex vector-matrix product
("each external product is essentially a vector-matrix multiplication",
paper §II-B), which is the MXU-friendly shape on a real TPU.

TPU mapping (see DESIGN.md §Hardware-Adaptation): the grid walks blocks of
`BLOCK` frequency bins; per step the working set is
R*BLOCK + R*C*BLOCK + C*BLOCK f64 pairs — for the paper's largest
parameters (N = 2^16, R = 6, C = 2) and BLOCK = 512 this is ~1.2 MB, well
inside VMEM, mirroring how the paper's accumulator buffer holds the GLWE
working set on-chip. Executed with interpret=True on CPU (Mosaic
custom-calls are TPU-only).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default number of frequency bins per grid step.
BLOCK = 256


def _mac_kernel(dec_re_ref, dec_im_ref, bsk_re_ref, bsk_im_ref,
                acc_re_ref, acc_im_ref):
    dr = dec_re_ref[...]  # (R, B)
    di = dec_im_ref[...]
    br = bsk_re_ref[...]  # (R, C, B)
    bi = bsk_im_ref[...]
    # Complex MAC as four real contractions over R.
    acc_re_ref[...] = jnp.einsum("rb,rcb->cb", dr, br) - jnp.einsum(
        "rb,rcb->cb", di, bi
    )
    acc_im_ref[...] = jnp.einsum("rb,rcb->cb", dr, bi) + jnp.einsum(
        "rb,rcb->cb", di, br
    )


@functools.partial(jax.jit, static_argnames=("block",))
def fourier_mac(dec_re, dec_im, bsk_re, bsk_im, block: int = BLOCK):
    """acc[c,h] = sum_r dec[r,h] * bsk[r,c,h] (complex, split re/im).

    Shapes: dec (R, H), bsk (R, C, H) -> (C, H); H must be divisible by
    `block` (all TFHE sizes here are powers of two >= 256).
    """
    r, h = dec_re.shape
    _, c, _ = bsk_re.shape
    blk = min(block, h)
    grid = (h // blk,)
    spec_dec = pl.BlockSpec((r, blk), lambda i: (0, i))
    spec_bsk = pl.BlockSpec((r, c, blk), lambda i: (0, 0, i))
    spec_acc = pl.BlockSpec((c, blk), lambda i: (0, i))
    out_shape = [
        jax.ShapeDtypeStruct((c, h), dec_re.dtype),
        jax.ShapeDtypeStruct((c, h), dec_re.dtype),
    ]
    return tuple(
        pl.pallas_call(
            _mac_kernel,
            grid=grid,
            in_specs=[spec_dec, spec_dec, spec_bsk, spec_bsk],
            out_specs=[spec_acc, spec_acc],
            out_shape=out_shape,
            interpret=True,
        )(dec_re, dec_im, bsk_re, bsk_im)
    )
