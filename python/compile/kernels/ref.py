"""Pure-jnp oracles for the Pallas kernels.

These are the correctness references pytest/hypothesis compare against
(`python/tests/test_kernel.py`); they are also usable as drop-in
replacements for the kernels (model.py takes a `use_pallas` flag).
"""

import jax.numpy as jnp


def fourier_mac_ref(dec_re, dec_im, bsk_re, bsk_im):
    """Fourier-domain external-product MAC (the paper's BRU VecMAC).

    acc[c, h] = sum_r dec[r, h] * bsk[r, c, h]   (complex)

    Args:
      dec_re, dec_im: f64[R, H] — decomposed GLWE rows in the Fourier domain.
      bsk_re, bsk_im: f64[R, C, H] — one GGSW in the Fourier domain.
    Returns:
      (acc_re, acc_im): f64[C, H].
    """
    acc_re = jnp.einsum("rh,rch->ch", dec_re, bsk_re) - jnp.einsum(
        "rh,rch->ch", dec_im, bsk_im
    )
    acc_im = jnp.einsum("rh,rch->ch", dec_re, bsk_im) + jnp.einsum(
        "rh,rch->ch", dec_im, bsk_re
    )
    return acc_re, acc_im


def decompose_ref(x, base_log: int, level: int):
    """Balanced gadget decomposition (the paper's Decomposer unit).

    Digit j has weight q/B^(j+1), j = 0 most significant; digits are
    balanced in [-B/2, B/2). Keeps the top base_log*level bits, rounded.

    Args:
      x: u64[...] torus values.
    Returns:
      i64[level, ...] digits.
    """
    x = x.astype(jnp.uint64)
    keep = base_log * level
    rounding = jnp.uint64(1 << (64 - keep - 1))
    res = (x + rounding) >> jnp.uint64(64 - keep)
    half = jnp.int64(1 << (base_log - 1))
    mask = jnp.uint64((1 << base_log) - 1)
    digits = []
    for _ in range(level):  # least significant kept digit first
        d = (res & mask).astype(jnp.int64)
        res = res >> jnp.uint64(base_log)
        carry = (d >= half).astype(jnp.int64)
        d = d - (carry << jnp.int64(base_log))
        res = res + carry.astype(jnp.uint64)
        digits.append(d)
    return jnp.stack(digits[::-1], axis=0)
