"""Pallas kernel: streaming gadget decomposition (the paper's Decomposer
unit, §IV-E).

The hardware unit is "an initial scaling unit ... and a continuous digit
extraction unit that outputs one integer per cycle with built-in rounding
logic". The kernel mirrors that structure: one rounding step, then `level`
digit-extraction steps with balanced-carry propagation, vectorized over a
block of coefficients. Executed with interpret=True on CPU.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 512


def _make_kernel(base_log: int, level: int):
    def kernel(x_ref, out_ref):
        x = x_ref[...].astype(jnp.uint64)  # (P, B)
        keep = base_log * level
        rounding = jnp.uint64(1 << (64 - keep - 1))
        res = (x + rounding) >> jnp.uint64(64 - keep)
        half = jnp.int64(1 << (base_log - 1))
        mask = jnp.uint64((1 << base_log) - 1)
        for j in range(level - 1, -1, -1):  # least significant first
            d = (res & mask).astype(jnp.int64)
            res = res >> jnp.uint64(base_log)
            carry = (d >= half).astype(jnp.int64)
            d = d - (carry << jnp.int64(base_log))
            res = res + carry.astype(jnp.uint64)
            out_ref[j, ...] = d

    return kernel


@functools.partial(jax.jit, static_argnames=("base_log", "level", "block"))
def decompose(x, base_log: int, level: int, block: int = BLOCK):
    """u64[P, N] -> i64[level, P, N] balanced gadget digits."""
    p, n = x.shape
    blk = min(block, n)
    grid = (n // blk,)
    return pl.pallas_call(
        _make_kernel(base_log, level),
        grid=grid,
        in_specs=[pl.BlockSpec((p, blk), lambda i: (0, i))],
        out_specs=pl.BlockSpec((level, p, blk), lambda i: (0, 0, i)),
        out_shape=jax.ShapeDtypeStruct((level, p, n), jnp.int64),
        interpret=True,
    )(x)
