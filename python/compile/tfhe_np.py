"""Pure-numpy reference implementation of multi-bit TFHE.

This is the functional oracle for the whole stack: the JAX/Pallas pipeline
(`model.py`, `kernels/`) is tested against it, and `rust/src/tfhe/` mirrors
it operation-for-operation (same gadget conventions, same FFT twist).

Everything here is build/test-path only; nothing imports numpy at serving
time. Torus = u64 with wrapping arithmetic throughout.
"""

from __future__ import annotations

import numpy as np

from .params import ParamSet

U64 = np.uint64
I64 = np.int64
_Q = float(2**64)


# --------------------------------------------------------------------------
# Negacyclic FFT (half-size complex FFT + twist), the paper's "double-real"
# representation (§IV-C): a degree-N real polynomial becomes an N/2-point
# complex vector.
# --------------------------------------------------------------------------

def twist(N: int) -> np.ndarray:
    j = np.arange(N // 2)
    return np.exp(-1j * np.pi * j / N)


def nfft(p_signed: np.ndarray, tw: np.ndarray | None = None) -> np.ndarray:
    """Forward negacyclic FFT of real (signed) coefficients, last axis N."""
    N = p_signed.shape[-1]
    if tw is None:
        tw = twist(N)
    # P(w_k) for w_k = zeta^(4k+1): fold as p_lo - i*p_hi (w^(N/2) = -i).
    z = (p_signed[..., : N // 2] - 1j * p_signed[..., N // 2 :]) * tw
    return np.fft.fft(z, axis=-1)


def nifft(Z: np.ndarray, tw: np.ndarray | None = None) -> np.ndarray:
    """Inverse of :func:`nfft`; returns real coefficients, last axis N."""
    Nh = Z.shape[-1]
    if tw is None:
        tw = twist(2 * Nh)
    z = np.fft.ifft(Z, axis=-1) * np.conj(tw)
    return np.concatenate([z.real, -z.imag], axis=-1)


def u64_to_signed_f64(x: np.ndarray) -> np.ndarray:
    """Reinterpret torus u64 as signed (centered) and convert to f64."""
    return x.astype(U64).view(I64).astype(np.float64)


def f64_to_u64(x: np.ndarray) -> np.ndarray:
    """Round to integer mod 2^64 (values may far exceed 64-bit range)."""
    r = x - np.round(x * (1.0 / _Q)) * _Q
    return np.round(r).astype(I64).view(U64)


def negacyclic_mul_naive(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """O(N^2) schoolbook multiplication in Z[X]/(X^N+1) (test oracle)."""
    N = a.shape[-1]
    out = np.zeros(N, dtype=np.float64)
    for i in range(N):
        for j in range(N):
            k = i + j
            if k < N:
                out[k] += a[i] * b[j]
            else:
                out[k - N] -= a[i] * b[j]
    return out


# --------------------------------------------------------------------------
# Gadget decomposition (balanced digits, closest representative).
# --------------------------------------------------------------------------

def decompose(x: np.ndarray, base_log: int, level: int) -> np.ndarray:
    """Decompose torus u64 -> `level` balanced digits in [-B/2, B/2).

    Returns i64 with a new leading axis of size `level`; digit j has weight
    q / B^(j+1) (j = 0 most significant). The decomposition keeps only the
    top `base_log*level` bits, rounded.
    """
    x = x.astype(U64)
    keep = base_log * level
    # Round to the closest multiple of 2^(64-keep).
    rounding = U64(1) << U64(64 - keep - 1)
    closest = (x + rounding) >> U64(64 - keep)
    digits = np.zeros((level,) + x.shape, dtype=I64)
    res = closest.astype(U64)
    half = I64(1) << I64(base_log - 1)
    mask = U64((1 << base_log) - 1)
    for j in range(level - 1, -1, -1):  # least significant digit first
        d = (res & mask).astype(I64)
        res = res >> U64(base_log)
        carry = (d >= half).astype(I64)
        d = d - (carry << I64(base_log))
        res = res + carry.astype(U64)
        digits[j] = d
    return digits


def recompose(digits: np.ndarray, base_log: int) -> np.ndarray:
    """Inverse of decompose up to the dropped low bits (returns u64)."""
    level = digits.shape[0]
    acc = np.zeros(digits.shape[1:], dtype=U64)
    for j in range(level):
        w = U64(64 - base_log * (j + 1))
        acc = acc + (digits[j].astype(I64).view(U64) << w)
    return acc


# --------------------------------------------------------------------------
# Keys and ciphertexts.
# --------------------------------------------------------------------------

class SecretKeys:
    """Client-side secrets: short LWE key, GLWE key, and the implied long
    (extracted) LWE key."""

    def __init__(self, p: ParamSet, rng: np.random.Generator):
        self.p = p
        self.lwe = rng.integers(0, 2, size=p.n, dtype=U64)
        self.glwe = rng.integers(0, 2, size=(p.k, p.N), dtype=U64)

    @property
    def long_lwe(self) -> np.ndarray:
        return self.glwe.reshape(-1)


def lwe_encrypt(msg_torus: int, key: np.ndarray, noise: float,
                rng: np.random.Generator) -> np.ndarray:
    """LWE ciphertext [a_0..a_{d-1}, b] with b = <a,s> + m + e."""
    d = key.shape[0]
    a = rng.integers(0, 2**64, size=d, dtype=U64)
    e = torus_gaussian(noise, rng)
    b = (np.sum(a * key, dtype=U64) + U64(msg_torus) + e)
    return np.concatenate([a, np.array([b], dtype=U64)])


def lwe_decrypt_phase(ct: np.ndarray, key: np.ndarray) -> int:
    """Raw phase b - <a,s> as u64."""
    return int(ct[-1] - np.sum(ct[:-1] * key, dtype=U64))


def torus_gaussian(sigma: float, rng: np.random.Generator) -> U64:
    return U64(I64(round(rng.normal(0.0, sigma) * _Q)) & I64(-1).view(I64))


def torus_gaussian_vec(sigma: float, shape, rng: np.random.Generator) -> np.ndarray:
    e = np.round(rng.normal(0.0, sigma, size=shape) * _Q)
    return e.astype(I64).view(U64)


def glwe_encrypt(msg_poly: np.ndarray, glwe_key: np.ndarray, noise: float,
                 rng: np.random.Generator) -> np.ndarray:
    """GLWE ciphertext: (k+1, N) u64; rows 0..k-1 mask, row k body."""
    k, N = glwe_key.shape
    a = rng.integers(0, 2**64, size=(k, N), dtype=U64)
    body = msg_poly.astype(U64) + torus_gaussian_vec(noise, N, rng)
    for c in range(k):
        body = body + poly_mul_u64(a[c], glwe_key[c])
    return np.concatenate([a, body[None, :]], axis=0)


def glwe_decrypt(ct: np.ndarray, glwe_key: np.ndarray) -> np.ndarray:
    k, N = glwe_key.shape
    phase = ct[k].copy()
    for c in range(k):
        phase = phase - poly_mul_u64(ct[c], glwe_key[c])
    return phase


def poly_mul_u64(a_torus: np.ndarray, b_int01: np.ndarray) -> np.ndarray:
    """Negacyclic product of a torus polynomial with a small integer (0/1
    key) polynomial, exact via integer convolution mod 2^64."""
    N = a_torus.shape[0]
    out = np.zeros(N, dtype=U64)
    nz = np.nonzero(b_int01.view(I64))[0]
    for j in nz:
        c = b_int01.view(I64)[j]
        rolled = np.empty(N, dtype=U64)
        if j == 0:
            rolled[:] = a_torus
        else:
            rolled[j:] = a_torus[: N - j]
            rolled[:j] = (np.zeros(j, dtype=U64) - a_torus[N - j :])
        out = out + U64(c) * rolled if c >= 0 else out - U64(-c) * rolled
    return out


# --------------------------------------------------------------------------
# Evaluation keys.
# --------------------------------------------------------------------------

def make_bsk(sk: SecretKeys, rng: np.random.Generator) -> np.ndarray:
    """Bootstrapping key: n GGSW encryptions of the short-LWE key bits.

    Shape (n, (k+1)*level, k+1, N) u64. Row r = c*level + j encrypts
    m * (-s_c) * q/B^(j+1) in the body direction c (for c<k) or
    m * q/B^(j+1) (c = k), following the gadget convention above.
    """
    p = sk.p
    rows = p.ggsw_rows
    bsk = np.zeros((p.n, rows, p.k + 1, p.N), dtype=U64)
    for i in range(p.n):
        m = int(sk.lwe[i])
        for c in range(p.k + 1):
            for j in range(p.bsk_level):
                w = U64(64 - p.bsk_base_log * (j + 1))
                msg = np.zeros(p.N, dtype=U64)
                if m:
                    if c < p.k:
                        # -s_c * q/B^(j+1): subtract key poly scaled.
                        msg = (np.zeros(p.N, dtype=U64) - sk.glwe[c]) << w
                    else:
                        msg[0] = U64(1) << w
                ct = glwe_encrypt(msg, sk.glwe, p.glwe_noise, rng)
                bsk[i, c * p.bsk_level + j] = ct
    return bsk


def bsk_to_fourier(bsk: np.ndarray) -> np.ndarray:
    """Complex BSK: (n, rows, k+1, N/2) complex128."""
    return nfft(u64_to_signed_f64(bsk))


def make_ksk(sk: SecretKeys, rng: np.random.Generator) -> np.ndarray:
    """Key-switching key long->short: (kN, ks_level, n+1) u64; entry (i, j)
    is an LWE_n encryption of s_long_i * q/B_ks^(j+1)."""
    p = sk.p
    long_key = sk.long_lwe
    ksk = np.zeros((p.long_dim, p.ks_level, p.n + 1), dtype=U64)
    for i in range(p.long_dim):
        for j in range(p.ks_level):
            w = U64(64 - p.ks_base_log * (j + 1))
            msg = int(U64(long_key[i]) << w)
            ksk[i, j] = lwe_encrypt(msg, sk.lwe, p.lwe_noise, rng)
    return ksk


# --------------------------------------------------------------------------
# PBS pipeline (key-switch first, paper §II-B).
# --------------------------------------------------------------------------

def keyswitch(ct_long: np.ndarray, ksk: np.ndarray, p: ParamSet) -> np.ndarray:
    """LWE_{kN} -> LWE_n using the KSK."""
    a, b = ct_long[:-1], ct_long[-1]
    out = np.zeros(p.n + 1, dtype=U64)
    out[-1] = b
    digits = decompose(a, p.ks_base_log, p.ks_level)  # (level, kN) i64
    for j in range(p.ks_level):
        d = digits[j].view(U64)  # signed digits as wrapping u64
        out = out - np.sum(d[:, None] * ksk[:, j, :], axis=0, dtype=U64)
    return out


def modswitch(ct: np.ndarray, N: int) -> np.ndarray:
    """Scale torus u64 -> Z_{2N} with rounding."""
    two_n = 2 * N
    shift = U64(64 - (two_n.bit_length() - 1))
    return (((ct >> (shift - U64(1))) + U64(1)) >> U64(1)).astype(U64) % U64(two_n)


def make_lut_poly(p: ParamSet, f) -> np.ndarray:
    """Test polynomial: v[j] = f(floor(j*P/2N)) * delta, then negacyclically
    pre-rotated by -box/2 so each message slot is *centered* on its phase
    (handles negative noise around m = 0 without a sign flip)."""
    P = p.plaintext_modulus
    box = 2 * p.N // P
    j = np.arange(p.N)
    m = (j // box) % P
    vals = np.array([f(int(mm)) % P for mm in m], dtype=U64)
    v = vals * U64(p.delta)
    return rotate_poly(v, 2 * p.N - box // 2)


def rotate_poly(poly: np.ndarray, r: int) -> np.ndarray:
    """Multiply by X^r in the negacyclic ring (r in [0, 2N))."""
    N = poly.shape[-1]
    r = r % (2 * N)
    ext = np.concatenate([poly, (np.zeros_like(poly) - poly)], axis=-1)
    idx = (np.arange(N) - r) % (2 * N)
    return ext[..., idx]


def external_product(ggsw_f: np.ndarray, glwe: np.ndarray, p: ParamSet) -> np.ndarray:
    """GGSW (Fourier, (rows, k+1, N/2) cplx) x GLWE ((k+1, N) u64) -> GLWE."""
    digits = decompose(glwe, p.bsk_base_log, p.bsk_level)  # (level, k+1, N)
    # Row order r = c*level + j.
    rows = np.transpose(digits, (1, 0, 2)).reshape(p.ggsw_rows, p.N)
    rows_f = nfft(rows.astype(np.float64))
    acc_f = np.einsum("rh,rch->ch", rows_f, ggsw_f)
    return f64_to_u64(nifft(acc_f))


def cmux_rotate(acc: np.ndarray, ggsw_f: np.ndarray, amount: int, p: ParamSet) -> np.ndarray:
    """acc <- acc + GGSW(s) box (X^amount * acc - acc)."""
    diff = rotate_poly(acc, amount) - acc
    return acc + external_product(ggsw_f, diff, p)


def blind_rotate(ct_short: np.ndarray, bsk_f: np.ndarray, lut_poly: np.ndarray,
                 p: ParamSet) -> np.ndarray:
    """Returns the rotated accumulator GLWE (k+1, N)."""
    msw = modswitch(ct_short, p.N)
    b = int(msw[-1])
    acc = np.zeros((p.k + 1, p.N), dtype=U64)
    acc[p.k] = rotate_poly(lut_poly, 2 * p.N - b)
    for i in range(p.n):
        a_i = int(msw[i])
        if a_i != 0:
            acc = cmux_rotate(acc, bsk_f[i], a_i, p)
    return acc


def sample_extract(glwe: np.ndarray, p: ParamSet) -> np.ndarray:
    """Extract LWE_{kN} of the constant coefficient."""
    k, N = p.k, p.N
    out = np.zeros(p.long_dim + 1, dtype=U64)
    for c in range(k):
        mask = glwe[c]
        a = np.empty(N, dtype=U64)
        a[0] = mask[0]
        a[1:] = np.zeros(N - 1, dtype=U64) - mask[:0:-1]
        out[c * N : (c + 1) * N] = a
    out[-1] = glwe[k][0]
    return out


def pbs(ct_long: np.ndarray, ksk: np.ndarray, bsk_f: np.ndarray,
        lut_poly: np.ndarray, p: ParamSet) -> np.ndarray:
    """Full programmable bootstrap, key-switch-first order."""
    short = keyswitch(ct_long, ksk, p)
    acc = blind_rotate(short, bsk_f, lut_poly, p)
    return sample_extract(acc, p)


# --------------------------------------------------------------------------
# Multi-bit message encode/decode.
# --------------------------------------------------------------------------

def encode(m: int, p: ParamSet) -> int:
    return (m % p.plaintext_modulus) * p.delta


def decode(phase: int, p: ParamSet) -> int:
    P = p.plaintext_modulus
    return int((U64(phase) + U64(p.delta // 2)) >> U64(64 - p.width - 1)) % P


def encrypt_long(m: int, sk: SecretKeys, rng: np.random.Generator) -> np.ndarray:
    return lwe_encrypt(encode(m, sk.p), sk.long_lwe, sk.p.glwe_noise, rng)


def decrypt_long(ct: np.ndarray, sk: SecretKeys) -> int:
    return decode(lwe_decrypt_phase(ct, sk.long_lwe), sk.p)
