import numpy as np
import pytest

np.seterr(over="ignore")  # torus arithmetic wraps by design

from compile import tfhe_np as T
from compile.params import TEST1


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(12345)


@pytest.fixture(scope="session")
def keys():
    """TEST1 secret keys + evaluation keys (session-cached: keygen is the
    slow part of the suite)."""
    rng = np.random.default_rng(2024)
    sk = T.SecretKeys(TEST1, rng)
    bsk = T.make_bsk(sk, rng)
    return {
        "sk": sk,
        "bsk": bsk,
        "bsk_f": T.bsk_to_fourier(bsk),
        "ksk": T.make_ksk(sk, rng),
        "rng": rng,
    }
