"""AOT export: HLO text artifacts + manifest round-trip."""

import json
import os

from compile import aot
from compile.params import TEST1


def test_export_writes_artifacts_and_manifest(tmp_path):
    man = aot.export(str(tmp_path), ["test1"])
    assert len(man["artifacts"]) == 2
    names = {a["name"] for a in man["artifacts"]}
    assert names == {"blind_rotate", "keyswitch"}
    for a in man["artifacts"]:
        path = tmp_path / a["file"]
        assert path.exists()
        text = path.read_text()
        # HLO text, not proto: must start with the module header.
        assert text.startswith("HloModule"), text[:40]
        assert a["params"]["n"] == TEST1.n
    # manifest json round-trips
    loaded = json.loads((tmp_path / "manifest.json").read_text())
    assert loaded == man


def test_blind_rotate_hlo_contains_fft_and_loop(tmp_path):
    aot.export(str(tmp_path), ["test1"])
    text = (tmp_path / "blind_rotate_test1.hlo.txt").read_text()
    assert "fft(" in text  # negacyclic FFT lowered to the HLO fft op
    assert "while(" in text  # fori_loop over n stayed rolled (compact HLO)
    assert "u64[" in text  # torus arithmetic is u64


def test_input_specs_match_model_shapes(tmp_path):
    man = aot.export(str(tmp_path), ["test1"])
    br = next(a for a in man["artifacts"] if a["name"] == "blind_rotate")
    by_name = {i["name"]: i for i in br["inputs"]}
    assert by_name["ct_short"]["shape"] == [TEST1.n + 1]
    assert by_name["bsk_re"]["shape"] == [
        TEST1.n, TEST1.ggsw_rows, TEST1.k + 1, TEST1.N // 2]
    assert by_name["lut_poly"]["dtype"] == "uint64"
