"""L1 kernel correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes/dtypes; assert_allclose against ref (the CORE
correctness signal for the kernels the AOT artifacts embed)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref as kref
from compile.kernels.decompose import decompose as decompose_pallas
from compile.kernels.fourier_mac import fourier_mac as fourier_mac_pallas

jax.config.update("jax_enable_x64", True)


# ---------------------------------------------------------------- fourier_mac

@settings(max_examples=20, deadline=None)
@given(
    r=st.integers(1, 8),
    c=st.integers(1, 3),
    log_h=st.integers(5, 10),
    dtype=st.sampled_from([np.float32, np.float64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_fourier_mac_matches_ref(r, c, log_h, dtype, seed):
    h = 1 << log_h
    rng = np.random.default_rng(seed)
    dec_re, dec_im = rng.normal(size=(2, r, h)).astype(dtype)
    bsk_re, bsk_im = rng.normal(size=(2, r, c, h)).astype(dtype)
    got_re, got_im = fourier_mac_pallas(dec_re, dec_im, bsk_re, bsk_im)
    exp_re, exp_im = kref.fourier_mac_ref(dec_re, dec_im, bsk_re, bsk_im)
    tol = 1e-5 if dtype == np.float32 else 1e-12
    np.testing.assert_allclose(got_re, exp_re, rtol=tol, atol=tol)
    np.testing.assert_allclose(got_im, exp_im, rtol=tol, atol=tol)


def test_fourier_mac_is_complex_vecmat():
    """Cross-check against an explicit complex einsum."""
    rng = np.random.default_rng(7)
    r, c, h = 6, 2, 256
    d = rng.normal(size=(r, h)) + 1j * rng.normal(size=(r, h))
    b = rng.normal(size=(r, c, h)) + 1j * rng.normal(size=(r, c, h))
    got_re, got_im = fourier_mac_pallas(
        d.real.copy(), d.imag.copy(), b.real.copy(), b.imag.copy()
    )
    exp = np.einsum("rh,rch->ch", d, b)
    np.testing.assert_allclose(np.asarray(got_re) + 1j * np.asarray(got_im),
                               exp, rtol=1e-12, atol=1e-12)


@pytest.mark.parametrize("block", [64, 128, 256])
def test_fourier_mac_block_invariance(block):
    rng = np.random.default_rng(3)
    r, c, h = 4, 2, 512
    args = [rng.normal(size=(r, h)), rng.normal(size=(r, h)),
            rng.normal(size=(r, c, h)), rng.normal(size=(r, c, h))]
    a_re, a_im = fourier_mac_pallas(*args, block=block)
    b_re, b_im = fourier_mac_pallas(*args, block=h)
    np.testing.assert_allclose(a_re, b_re, rtol=1e-13)
    np.testing.assert_allclose(a_im, b_im, rtol=1e-13)


# ------------------------------------------------------------------ decompose

@settings(max_examples=20, deadline=None)
@given(
    base_log=st.integers(2, 16),
    level=st.integers(1, 6),
    p=st.integers(1, 3),
    log_n=st.integers(5, 10),
    seed=st.integers(0, 2**31 - 1),
)
def test_decompose_matches_ref(base_log, level, p, log_n, seed):
    if base_log * level > 60:
        return
    n = 1 << log_n
    rng = np.random.default_rng(seed)
    x = rng.integers(0, 2**64, size=(p, n), dtype=np.uint64)
    got = np.asarray(decompose_pallas(x, base_log, level))
    exp = np.asarray(kref.decompose_ref(jnp.asarray(x), base_log, level))
    np.testing.assert_array_equal(got, exp)


@settings(max_examples=15, deadline=None)
@given(
    base_log=st.integers(2, 15),
    level=st.integers(1, 4),
    seed=st.integers(0, 2**31 - 1),
)
def test_decompose_digits_balanced_and_close(base_log, level, seed):
    """Recomposition error < q/2^(base_log*level) and digits in [-B/2, B/2]."""
    if base_log * level > 60:
        return
    rng = np.random.default_rng(seed)
    x = rng.integers(0, 2**64, size=(1, 256), dtype=np.uint64)
    d = np.asarray(decompose_pallas(x, base_log, level))
    half = 1 << (base_log - 1)
    assert d.min() >= -half and d.max() <= half
    acc = np.zeros_like(x)
    for j in range(level):
        w = np.uint64(64 - base_log * (j + 1))
        acc = acc + (d[j].view(np.uint64) << w)
    err = (acc - x).view(np.int64).astype(np.float64) / 2.0**64
    assert np.abs(err).max() <= 2.0 ** -(base_log * level) * 0.5 + 1e-18


def test_decompose_zero_and_extremes():
    x = np.array([[0, 1, 2**63, 2**64 - 1]], dtype=np.uint64)
    d = np.asarray(decompose_pallas(x, 8, 3))
    # zero decomposes to zero digits; 2^64-1 rounds to 0 (wraps).
    assert (d[:, 0, 0] == 0).all()
    assert (d[:, 0, 3] == 0).all()
    # 2^63 -> most significant digit -128 (balanced) with carry upward.
    assert d[0, 0, 2] == -128
