"""L2 model correctness: the JAX pipeline (with Pallas kernels) vs the
numpy oracle, stage by stage and end-to-end, plus a zero-noise exactness
test."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile import tfhe_np as T
from compile.params import TEST1 as P

jax.config.update("jax_enable_x64", True)


def test_modswitch_matches_numpy():
    rng = np.random.default_rng(0)
    x = rng.integers(0, 2**64, 257, dtype=np.uint64)
    got = np.asarray(M.modswitch(jnp.asarray(x), P.N))
    exp = T.modswitch(x, P.N).astype(np.int64)
    np.testing.assert_array_equal(got, exp)


def test_nfft_matches_numpy():
    rng = np.random.default_rng(1)
    p = rng.normal(0, 2**30, (3, P.N))
    tw = M.twist(P.N)
    got = np.asarray(M.nfft(jnp.asarray(p), tw))
    np.testing.assert_allclose(got, T.nfft(p), rtol=1e-10)
    back = np.asarray(M.nifft(jnp.asarray(got), tw))
    np.testing.assert_allclose(back, p, rtol=1e-9)


def test_rotate_glwe_matches_numpy():
    rng = np.random.default_rng(2)
    g = rng.integers(0, 2**64, (2, 64), dtype=np.uint64)
    for r in [0, 1, 63, 64, 100, 127]:
        got = np.asarray(M.rotate_glwe(jnp.asarray(g), r, 64))
        exp = T.rotate_poly(g, r)
        np.testing.assert_array_equal(got, exp)


@pytest.mark.parametrize("use_pallas", [True, False], ids=["pallas", "jnp-ref"])
def test_keyswitch_matches_numpy(keys, use_pallas):
    sk, ksk, rng = keys["sk"], keys["ksk"], keys["rng"]
    ct = T.encrypt_long(5, sk, rng)
    got = np.asarray(M.keyswitch(jnp.asarray(ct), jnp.asarray(ksk), P, use_pallas))
    exp = T.keyswitch(ct, ksk, P)
    np.testing.assert_array_equal(got, exp)


@pytest.mark.parametrize("use_pallas", [True, False], ids=["pallas", "jnp-ref"])
def test_external_product_matches_numpy(keys, use_pallas):
    sk, bsk_f, rng = keys["sk"], keys["bsk_f"], keys["rng"]
    glwe = T.glwe_encrypt(T.make_lut_poly(P, lambda m: m), sk.glwe,
                          P.glwe_noise, rng)
    tw = M.twist(P.N)
    got = np.asarray(
        M.external_product(
            jnp.asarray(bsk_f[0].real), jnp.asarray(bsk_f[0].imag),
            jnp.asarray(glwe), P, tw, use_pallas,
        )
    )
    exp = T.external_product(bsk_f[0], glwe, P)
    # Same math, but jnp (ducc) and numpy (pocketfft) FFTs round differently;
    # the divergence must stay far below the torus noise budget (~2^-37 of
    # the torus for TEST1, vs a decision boundary of 2^-5).
    diff = np.abs((got - exp).view(np.int64)).max() / 2.0**64
    assert diff < 2.0**-34, f"fft-path divergence {diff} of the torus"


def test_blind_rotate_matches_numpy_phase(keys):
    sk, ksk, bsk_f, rng = keys["sk"], keys["ksk"], keys["bsk_f"], keys["rng"]
    lut = T.make_lut_poly(P, lambda m: (3 * m) % 16)
    ct = T.encrypt_long(2, sk, rng)
    short = T.keyswitch(ct, ksk, P)
    got = np.asarray(
        M.blind_rotate(jnp.asarray(short), jnp.asarray(bsk_f.real),
                       jnp.asarray(bsk_f.imag), jnp.asarray(lut), P)
    )
    exp = T.blind_rotate(short, bsk_f, lut, P)
    # FFT-path rounding can flip single gadget digits, so the two
    # trajectories diverge at the digit-cutoff scale (2^-24) accumulated
    # over n iterations — still orders of magnitude below the decision
    # boundary (2^-5).
    d = (T.glwe_decrypt(got, sk.glwe) - T.glwe_decrypt(exp, sk.glwe))
    err = np.abs(d.view(np.int64)).max() / 2.0**64
    assert err < 2.0**-14, f"phase divergence {err}"


def test_full_pbs_jax_pipeline(keys):
    """KS (jax) -> BR (jax) -> extract -> decrypt must evaluate the LUT."""
    sk, ksk, bsk_f, rng = keys["sk"], keys["ksk"], keys["bsk_f"], keys["rng"]
    f = lambda m: (m * 3 + 1) % 16
    lut = T.make_lut_poly(P, f)
    ks_fn, _, _ = M.build_keyswitch(P)
    br_fn, _, _ = M.build_blind_rotate(P)
    for m in range(8):
        ct = T.encrypt_long(m, sk, rng)
        short = np.asarray(ks_fn(jnp.asarray(ct), jnp.asarray(ksk))[0])
        acc = np.asarray(
            br_fn(jnp.asarray(short), jnp.asarray(bsk_f.real),
                  jnp.asarray(bsk_f.imag), jnp.asarray(lut))[0]
        )
        out = T.sample_extract(acc, P)
        assert T.decrypt_long(out, sk) == f(m), f"m={m}"


def test_zero_noise_pbs_is_exact():
    """With zero encryption noise the only residual error is the gadget
    digit cutoff (2^-24 per external product, accumulated over n
    iterations) — the phase must sit on the encoded lattice point to well
    within the decision boundary."""
    P0 = dataclasses.replace(P, lwe_noise=0.0, glwe_noise=0.0)
    rng = np.random.default_rng(77)
    sk = T.SecretKeys(P0, rng)
    bsk_f = T.bsk_to_fourier(T.make_bsk(sk, rng))
    ksk = T.make_ksk(sk, rng)
    lut = T.make_lut_poly(P0, lambda m: m ^ 5)
    ks_fn, _, _ = M.build_keyswitch(P0)
    br_fn, _, _ = M.build_blind_rotate(P0)
    for m in [0, 1, 6, 7]:
        ct = T.encrypt_long(m, sk, rng)
        short = np.asarray(ks_fn(jnp.asarray(ct), jnp.asarray(ksk))[0])
        acc = np.asarray(
            br_fn(jnp.asarray(short), jnp.asarray(bsk_f.real),
                  jnp.asarray(bsk_f.imag), jnp.asarray(lut))[0]
        )
        out = T.sample_extract(acc, P0)
        ph = T.lwe_decrypt_phase(out, sk.long_lwe)
        delta = (ph - T.encode(m ^ 5, P0)) % 2**64
        err = abs(np.array(delta, dtype=np.uint64).view(np.int64)[()]) / 2.0**64
        assert err < 2.0**-15, f"m={m} err={err}"
        assert T.decrypt_long(out, sk) == (m ^ 5)
