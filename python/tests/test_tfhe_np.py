"""Self-tests of the numpy reference TFHE (the oracle everything else is
checked against), including full-PBS functional correctness."""

import dataclasses

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import tfhe_np as T
from compile.params import TEST1 as P


def test_encrypt_decrypt_roundtrip(keys):
    sk, rng = keys["sk"], keys["rng"]
    for m in range(P.plaintext_modulus // 2):
        ct = T.encrypt_long(m, sk, rng)
        assert T.decrypt_long(ct, sk) == m


def test_lwe_homomorphic_add(keys):
    sk, rng = keys["sk"], keys["rng"]
    a = T.encrypt_long(2, sk, rng)
    b = T.encrypt_long(3, sk, rng)
    assert T.decrypt_long(a + b, sk) == 5


def test_lwe_plaintext_mul(keys):
    sk, rng = keys["sk"], keys["rng"]
    a = T.encrypt_long(3, sk, rng)
    assert T.decrypt_long(a * np.uint64(2), sk) == 6


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), log_n=st.integers(3, 7))
def test_fft_convolution_vs_naive(seed, log_n):
    rng = np.random.default_rng(seed)
    n = 1 << log_n
    a = rng.normal(0, 50, n).round()
    b = rng.normal(0, 50, n).round()
    fast = T.nifft(T.nfft(a) * T.nfft(b))
    naive = T.negacyclic_mul_naive(a, b)
    np.testing.assert_allclose(fast, naive, atol=1e-5)


def test_nfft_roundtrip():
    rng = np.random.default_rng(1)
    p = rng.normal(0, 2**40, 512)
    np.testing.assert_allclose(T.nifft(T.nfft(p)), p, rtol=1e-9)


def test_rotate_poly_negacyclic_wrap():
    v = np.arange(8, dtype=np.uint64)
    r1 = T.rotate_poly(v, 1)  # X * v
    assert r1[0] == np.uint64(0) - np.uint64(7)  # -v[7]
    assert (r1[1:] == v[:-1]).all()
    # X^(2N) = identity, X^N = -1.
    assert (T.rotate_poly(v, 16) == v).all()
    assert (T.rotate_poly(v, 8) == np.zeros(8, np.uint64) - v).all()


def test_sample_extract_preserves_constant_phase(keys):
    sk, rng = keys["sk"], keys["rng"]
    msg = np.zeros(P.N, dtype=np.uint64)
    msg[0] = T.encode(5, P)
    glwe = T.glwe_encrypt(msg, sk.glwe, P.glwe_noise, rng)
    lwe = T.sample_extract(glwe, P)
    assert T.decrypt_long(lwe, sk) == 5


def test_keyswitch_preserves_message(keys):
    sk, ksk, rng = keys["sk"], keys["ksk"], keys["rng"]
    for m in [0, 3, 7]:
        ct = T.encrypt_long(m, sk, rng)
        short = T.keyswitch(ct, ksk, P)
        ph = T.lwe_decrypt_phase(short, sk.lwe)
        assert T.decode(ph, P) == m


def test_modswitch_rounding():
    N = 512
    x = np.array([0, 2**54, 2**54 - 1, 2**63, 2**64 - 1], dtype=np.uint64)
    got = T.modswitch(x, N)
    # 2^54 on the torus = 1/1024 of it = exactly 1 step of 2N=1024.
    assert list(got) == [0, 1, 1, 512, 0]


@pytest.mark.parametrize(
    "f",
    [lambda m: m, lambda m: (m * m + 1) % 16, lambda m: max(m - 3, 0),
     lambda m: 15 - m],
    ids=["id", "square", "relu", "neg"],
)
def test_full_pbs_evaluates_lut(keys, f):
    sk, ksk, bsk_f, rng = keys["sk"], keys["ksk"], keys["bsk_f"], keys["rng"]
    lut = T.make_lut_poly(P, f)
    for m in range(8):
        ct = T.encrypt_long(m, sk, rng)
        out = T.pbs(ct, ksk, bsk_f, lut, P)
        assert T.decrypt_long(out, sk) == f(m) % 16, f"m={m}"


def test_pbs_refreshes_noise(keys):
    """Output noise must be independent of (and smaller than) input noise."""
    sk, ksk, bsk_f, rng = keys["sk"], keys["ksk"], keys["bsk_f"], keys["rng"]
    lut = T.make_lut_poly(P, lambda m: m)
    noisy_p = dataclasses.replace(P, glwe_noise=2.0**-14)
    ct = T.lwe_encrypt(T.encode(4, P), sk.long_lwe, noisy_p.glwe_noise, rng)
    out = T.pbs(ct, ksk, bsk_f, lut, P)
    ph = T.lwe_decrypt_phase(out, sk.long_lwe)
    delta = (ph - T.encode(4, P)) % 2**64
    err = abs(np.array(delta, dtype=np.uint64).view(np.int64)[()]) / 2.0**64
    assert err < 2.0**-9, f"post-PBS noise too big: {err}"


def test_external_product_zero_ggsw_gives_noise_only(keys):
    """GGSW(0) box GLWE ~ encryption of 0."""
    sk, rng = keys["sk"], keys["rng"]
    zero_bits = T.SecretKeys(P, rng)
    zero_bits.lwe = np.zeros(P.n, dtype=np.uint64)
    zero_bits.glwe = sk.glwe
    g = T.bsk_to_fourier(T.make_bsk(zero_bits, rng)[:1])[0]
    glwe = T.glwe_encrypt(np.full(P.N, T.encode(3, P), np.uint64),
                          sk.glwe, P.glwe_noise, rng)
    out = T.external_product(g, glwe, P)
    dec = T.glwe_decrypt(out, sk.glwe).view(np.int64).astype(np.float64) / 2**64
    assert np.abs(dec).max() < 2.0**-10
